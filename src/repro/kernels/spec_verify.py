"""Pallas TPU multi-token verify: flash attention for speculative decoding.

Speculative decoding (DESIGN.md §6.1-spec) verifies ``K = spec_k + 1`` new
tokens — the pending token plus k draft tokens — in ONE target forward
against the paged KV pool.  By the time attention runs, the K tokens' KV has
already been scattered into pool pages at positions
``lengths[b] .. lengths[b]+K-1``; what distinguishes this kernel from the
single-token ``paged_decode`` is the *per-query* causal bound: draft query
``j`` (absolute position ``lengths[b] + j``) may attend positions
``<= lengths[b] + j``, so each query row of the block gets its own length
limit instead of the row-wide scalar.

The block-table indirection is identical to ``paged_decode``: the table and
per-row base lengths are scalar-prefetched to SMEM, and the BlockSpec
``index_map`` resolves logical page ``ip`` of row ``b`` to physical page
``bt[b, ip]`` so the pager can stream pool pages HBM->VMEM ahead of the
body.  One grid step covers one page per (batch row × kv head); the K query
positions of all ``rep`` grouped heads ride in one ``(K*rep, d)`` q block,
with the online-softmax carry in VMEM scratch.

Entries of the block table past a row's allocation may point anywhere (the
engine points them at the scratch page 0); they are DMA'd but fully masked.
The jnp oracle is ``ref.paged_verify_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallascompat import tpu_compiler_params
from repro.models.attention import NEG_INF


def _verify_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, page: int, hkv: int,
                   rep: int, scale: float):
    ip = pl.program_id(1)
    np_ = pl.num_programs(1)
    base_len = len_ref[pl.program_id(0) // hkv]

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (K*rep, d)
    k = k_ref[0].astype(jnp.float32)                   # (page, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    # logical token positions of this page vs each query's own causal bound:
    # q block row r is draft query j = r // rep, at absolute position
    # base_len + j, attending positions <= base_len + j
    k_pos = ip * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], 1), 0) // rep
    s = jnp.where(k_pos <= base_len + q_idx, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(ip == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_paged_verify_tpu(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = True) -> jax.Array:
    """q: (B, K, H, D) — K new tokens per row, whose KV is already in the
    pool at positions ``lengths[b] .. lengths[b]+K-1``; pools:
    (P, page, Hkv, D); block_tables: (B, maxp) int32; lengths: (B,) int32
    valid tokens per row BEFORE the K new tokens.

    Returns (B, K, H, D).
    """
    b, kq, h, d = q.shape
    page, hkv = k_pool.shape[1], k_pool.shape[2]
    maxp = block_tables.shape[1]
    assert h % hkv == 0
    rep = h // hkv

    # (B, K, H, D) -> (B*Hkv, K*rep, D): group the rep query heads of each
    # kv head, keeping the K draft positions adjacent so the kernel can
    # recover each q-block row's draft index as row // rep
    qr = (q.reshape(b, kq, hkv, rep, d).transpose(0, 2, 1, 3, 4)
          .reshape(b * hkv, kq * rep, d))
    kr = k_pool.transpose(0, 2, 1, 3).reshape(-1, page, d)
    vr = v_pool.transpose(0, 2, 1, 3).reshape(-1, page, d)
    bt = block_tables.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def kv_index(bh, ip, bt_ref, len_ref):
        # physical page for (row bh//hkv, logical page ip), head bh%hkv
        return (bt_ref[bh // hkv, ip] * hkv + bh % hkv, 0, 0)

    grid = (b * hkv, maxp)
    kernel = functools.partial(_verify_kernel, page=page, hkv=hkv, rep=rep,
                               scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, kq * rep, d),
                             lambda bh, ip, bt, ln: (bh, 0, 0)),
                pl.BlockSpec((1, page, d), kv_index),
                pl.BlockSpec((1, page, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, kq * rep, d),
                                   lambda bh, ip, bt, ln: (bh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((kq * rep, d), jnp.float32),
                pltpu.VMEM((kq * rep,), jnp.float32),
                pltpu.VMEM((kq * rep,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b * hkv, kq * rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lens, qr, kr, vr)
    return (out.reshape(b, hkv, kq, rep, d).transpose(0, 2, 1, 3, 4)
            .reshape(b, kq, h, d))
