"""Step builders for the dry-run / launchers: train_step, prefill_step,
serve_step (single decode token), parameterized per architecture."""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import InputShape
from repro.models import registry
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import make_train_step

# per-(family) lowering knobs: microbatch count for train_4k, attention chunks
TRAIN_MICROBATCHES = {
    "dbrx-132b": 8, "command-r-plus-104b": 8, "qwen3-32b": 8,
    "recurrentgemma-9b": 4, "qwen3-8b": 4, "starcoder2-7b": 4,
    "qwen2-vl-7b": 4, "granite-moe-1b-a400m": 2, "xlstm-1.3b": 2,
    "whisper-base": 1,
}


def microbatches_for(cfg: ModelConfig, shape: InputShape) -> int:
    mb = TRAIN_MICROBATCHES.get(cfg.name.replace("-window", ""), 4)
    while shape.global_batch % mb != 0:
        mb //= 2
    return max(mb, 1)


def build_train_step(cfg: ModelConfig, shape: InputShape,
                     microbatches: Optional[int] = None,
                     q_chunk: int = 1024, kv_chunk: int = 1024,
                     skip_masked_blocks: bool = False):
    opt = AdamWConfig()
    mb = microbatches or microbatches_for(cfg, shape)
    kw = dict(q_chunk=q_chunk, kv_chunk=kv_chunk)
    if cfg.family in ("dense", "vlm") and skip_masked_blocks:
        kw["skip_masked_blocks"] = True
    if cfg.family == "ssm":
        kw = {"chunk": 256}
    if cfg.family == "audio":
        kw = {"q_chunk": q_chunk}
    return make_train_step(cfg, opt, microbatches=mb, **kw)


def build_prefill_step(cfg: ModelConfig, shape: InputShape,
                       q_chunk: int = 1024, kv_chunk: int = 1024):
    fam = registry.get_family(cfg)
    kw = dict(q_chunk=q_chunk, kv_chunk=kv_chunk)
    if cfg.family == "ssm":
        kw = {"chunk": 256}
    if cfg.family == "audio":
        kw = {"q_chunk": q_chunk}

    def prefill_step(params, batch):
        return fam.prefill(params, cfg, batch, capacity=shape.seq_len, **kw)

    return prefill_step


def build_serve_step(cfg: ModelConfig, shape: InputShape):
    """One decode token against a cache of length seq_len."""
    fam = registry.get_family(cfg)

    def serve_step(params, cache, token):
        return fam.decode_step(params, cfg, cache, token)

    return serve_step
