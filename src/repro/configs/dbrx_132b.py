"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE, 16 experts top-4."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,                  # per-expert FFN width
    vocab_size=100352,
    head_dim=128,
    n_experts=16,
    top_k=4,
    norm_type="layernorm",
    rope_theta=5e5,
)
