"""Speculative-decoding subsystem (DESIGN.md §6.1-spec).

Five families of tests:

1.  Acceptance model — ``spec_expected_tokens`` hits its closed-form
    boundary values and the simulated ``SpecTokenBucketExecutor`` reduces
    to prefill + output/(decode * speedup) exactly for a lone stream.
2.  Engine parity — ``Engine(spec_draft=..., spec_k=...)`` greedy outputs
    are bit-identical to the plain paged engine (the repo's standing
    invariant), with an agreeing draft (every draft accepted), a
    disagreeing draft (rejection path), under page-pool preemption
    round-trips, and property-tested across random ``spec_k``, prompt
    lengths, and pool geometries.
3.  Multi-token emission — EOS inside an accepted draft run truncates
    exactly like single-token decode; budgets are never exceeded.
4.  Sim-vs-engine agreement — identical admit/deny sequences on identical
    page budgets, and both executors boot reporting the same
    ``expected_tokens_per_step`` because the engine's EMA is seeded from
    the sim's ``SPEC_ALPHA0`` constant.
5.  Acceptance-aware dispatch — ``Network._phase_pressure`` discounts a
    spec node's decode pressure and ``_est_wait`` scales its effective
    decode capacity, so decode-heavy requests chase spec-enabled nodes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Network, Node, NodePolicy
from repro.core.node import QueuedRequest
from repro.sim import (BackendProfile, EventLoop, SpecTokenBucketExecutor,
                       TokenBucketExecutor)
from repro.sim.executor import spec_expected_tokens
from repro.sim.servicemodel import SPEC_ALPHA0
from repro.sim.workload import Request


def _qr(rid, prompt, output, t=0.0):
    return QueuedRequest(
        Request(rid=rid, origin="n", arrival=t, prompt_tokens=prompt,
                output_tokens=output, slo_s=600.0),
        enqueue_time=t, delegated=False, origin_node="n")


class _Harness:
    """A SpecTokenBucketExecutor on a bare loop, recording completions."""

    def __init__(self, profile, **kw):
        self.loop = EventLoop()
        self.ex = SpecTokenBucketExecutor(profile, **kw)
        self.done = {}
        self.ex.bind(self.loop, self._cb)

    def _cb(self, qr, started_at, first_token_at):
        self.done[qr.req.rid] = dict(finish=self.loop.now,
                                     started=started_at,
                                     first_token=first_token_at)


PROF = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                      max_concurrency=8, quality=0.5, kv_token_budget=4096)


# ---------------------------------------------------------------------------
# 1. the acceptance model + sim analytics
# ---------------------------------------------------------------------------

class TestAcceptanceModel:
    def test_boundaries(self):
        # alpha = 0: every draft rejected, only the pending token lands
        assert spec_expected_tokens(0.0, 4) == 1.0
        # alpha = 1: all k drafts plus the bonus token
        assert spec_expected_tokens(1.0, 4) == 5.0
        # k = 0 degenerates to plain decode
        assert spec_expected_tokens(0.9, 0) == 1.0

    def test_closed_form(self):
        a, k = 0.7, 4
        assert spec_expected_tokens(a, k) == pytest.approx(
            sum(a ** i for i in range(k + 1)))

    def test_monotone_in_alpha_and_k(self):
        prev = 0.0
        for a in (0.0, 0.2, 0.5, 0.8, 0.99):
            e = spec_expected_tokens(a, 4)
            assert e > prev
            prev = e
        assert spec_expected_tokens(0.6, 6) > spec_expected_tokens(0.6, 2)

    def test_clipped_outside_unit_interval(self):
        assert spec_expected_tokens(-0.3, 3) == 1.0
        assert spec_expected_tokens(1.7, 3) == 4.0


class TestSpecSimExecutor:
    def test_single_request_service_time(self):
        """A lone stream finishes in prefill + output over the
        speedup-scaled decode rate — the analytic reduction."""
        h = _Harness(PROF, spec_k=4, spec_alpha=0.7, spec_overhead=0.15)
        assert h.ex.admit(_qr("a", 200, 500))
        h.loop.run()
        speedup = spec_expected_tokens(0.7, 4) / 1.15
        expected = 200 / PROF.prefill_tps + 500 / (PROF.decode_tps * speedup)
        assert h.done["a"]["finish"] == pytest.approx(expected, rel=1e-6)

    def test_alpha_zero_with_free_draft_matches_plain_bucket(self):
        """alpha=0, overhead=0 degenerates to the plain TokenBucketExecutor."""
        h = _Harness(PROF, spec_k=4, spec_alpha=0.0, spec_overhead=0.0)
        assert h.ex.admit(_qr("a", 100, 300))
        h.loop.run()
        loop2, done2 = EventLoop(), {}
        plain = TokenBucketExecutor(PROF)
        plain.bind(loop2, lambda qr, s, f: done2.update({qr.req.rid: loop2.now}))
        assert plain.admit(_qr("a", 100, 300))
        loop2.run()
        assert h.done["a"]["finish"] == pytest.approx(done2["a"], rel=1e-9)

    def test_load_reports_expected_tokens_per_step(self):
        h = _Harness(PROF, spec_k=3, spec_alpha=0.5)
        ld = h.ex.load()
        assert ld.expected_tokens_per_step == pytest.approx(
            spec_expected_tokens(0.5, 3))
        # non-spec backends report the neutral 1.0 default
        plain = TokenBucketExecutor(PROF)
        plain.bind(EventLoop(), lambda *a: None)
        assert plain.load().expected_tokens_per_step == 1.0

    def test_estimate_scales_with_speedup(self):
        h = _Harness(PROF, spec_k=4, spec_alpha=0.8, spec_overhead=0.1)
        plain_loop = EventLoop()
        plain = TokenBucketExecutor(PROF)
        plain.bind(plain_loop, lambda *a: None)
        assert h.ex.estimate(256, 512) < plain.estimate(256, 512)

    def test_admission_identical_to_plain_bucket(self):
        """Speculation never changes WHAT fits, only how fast it drains:
        the page/token admission rule is inherited unchanged."""
        for kw in (dict(), dict(page_size=64)):
            loop_a, loop_b = EventLoop(), EventLoop()
            spec = SpecTokenBucketExecutor(PROF, spec_alpha=0.9, **kw)
            plain = TokenBucketExecutor(PROF, **kw)
            spec.bind(loop_a, lambda *a: None)
            plain.bind(loop_b, lambda *a: None)
            decisions = []
            for i, (p, o) in enumerate(((1000, 1000), (1500, 1500),
                                        (500, 500), (2000, 2000))):
                decisions.append((spec.admit(_qr(f"s{i}", p, o)),
                                  plain.admit(_qr(f"p{i}", p, o))))
            for s, p in decisions:
                assert s == p


# ---------------------------------------------------------------------------
# 2. real-engine parity (the standing bit-parity invariant)
# ---------------------------------------------------------------------------

_MODEL_CACHE = {}


def _smoke_model():
    if "cp" not in _MODEL_CACHE:
        import jax
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        _MODEL_CACHE["cp"] = (cfg, registry.init(jax.random.PRNGKey(0), cfg))
    return _MODEL_CACHE["cp"]


def _draft_model():
    if "draft" not in _MODEL_CACHE:
        import jax
        from repro.models import registry
        cfg, _ = _smoke_model()
        dcfg = cfg.draft()
        _MODEL_CACHE["draft"] = (dcfg,
                                 registry.init(jax.random.PRNGKey(9), dcfg))
    return _MODEL_CACHE["draft"]


@pytest.fixture(scope="module")
def setup():
    return _smoke_model()


def _mk_reqs(seed, n=4, max_prompt=24, max_new_hi=10):
    from repro.serving import GenRequest
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(5, max_prompt + 1))
        out.append(GenRequest(
            rid=f"r{i}",
            tokens=rng.integers(2, 400, size=plen).astype(np.int32),
            max_new=int(rng.integers(2, max_new_hi + 1))))
    return out


def _results_by_rid(reqs):
    return {r.rid: np.asarray(r.result) for r in reqs}


class TestSpecEngineParity:
    def test_agreeing_draft_matches_paged_and_saves_steps(self, setup):
        """Draft == target: every draft is accepted, outputs are
        bit-identical, and the spec engine takes strictly fewer target
        forwards than the plain paged engine."""
        from repro.serving import Engine
        cfg, params = setup
        ref = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                     page_size=16)
        a = _results_by_rid(ref.serve(_mk_reqs(3)))
        spec = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                      page_size=16, spec_draft=(cfg, params), spec_k=3)
        b = _results_by_rid(spec.serve(_mk_reqs(3)))
        assert set(a) == set(b)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert spec.stats.spec_steps < ref.stats.decode_steps
        # an agreeing draft accepts every draft at every verify
        assert spec.stats.spec_accepted == spec.stats.spec_drafted > 0
        assert sum(spec.spec_accept_hist) == spec.spec_accept_hist[3] > 0
        assert spec.load_snapshot()["pages_used"] == 0

    def test_disagreeing_draft_matches_paged(self, setup):
        """A random tiny draft mostly disagrees: the rejection path runs,
        the EMA falls below its seed, and outputs stay bit-identical."""
        from repro.serving import Engine
        cfg, params = setup
        dcfg, dparams = _draft_model()
        ref = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                     page_size=16)
        a = _results_by_rid(ref.serve(_mk_reqs(5)))
        spec = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                      page_size=16, spec_draft=(dcfg, dparams), spec_k=3)
        b = _results_by_rid(spec.serve(_mk_reqs(5)))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert spec.stats.spec_accepted < spec.stats.spec_drafted
        assert spec.spec_alpha < SPEC_ALPHA0
        assert spec.load_snapshot()["pages_used"] == 0

    def test_tight_pool_preempts_and_stays_bit_identical(self, setup):
        """Page-pool pressure under multi-token lookahead preempts LIFO;
        the greedy restart reproduces outputs bit-identically."""
        from repro.serving import Engine
        cfg, params = setup
        ref = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                     page_size=16)
        a = _results_by_rid(ref.serve(_mk_reqs(7, n=5, max_new_hi=16)))
        spec = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                      page_size=16, num_pages=6,
                      spec_draft=(cfg, params), spec_k=2)
        b = _results_by_rid(spec.serve(_mk_reqs(7, n=5, max_new_hi=16)))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert spec.stats.preempted > 0
        snap = spec.load_snapshot()
        assert snap["pages_used"] == 0
        assert snap["free_pages"] == snap["pages_total"]

    @given(spec_k=st.integers(1, 3), seed=st.integers(0, 10**6),
           agreeing=st.booleans())
    @settings(max_examples=3, deadline=None)
    def test_random_workload_parity(self, spec_k, seed, agreeing):
        """Property: spec == paged greedy outputs across random spec_k,
        prompt lengths, budgets, and draft quality."""
        from repro.serving import Engine
        cfg, params = _smoke_model()
        draft = (cfg, params) if agreeing else _draft_model()
        ref = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=16)
        a = _results_by_rid(ref.serve(_mk_reqs(seed)))
        spec = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=16, spec_draft=draft, spec_k=spec_k)
        b = _results_by_rid(spec.serve(_mk_reqs(seed)))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])

    @pytest.mark.slow
    @given(spec_k=st.integers(1, 4), page_size=st.sampled_from([8, 16]),
           pool=st.integers(4, 10), seed=st.integers(0, 10**6),
           agreeing=st.booleans())
    @settings(max_examples=8, deadline=None)
    def test_random_geometry_parity_deep(self, spec_k, page_size, pool,
                                         seed, agreeing):
        """Deeper sweep (``-m slow``): random pool geometries force
        preemption round-trips under multi-token lookahead."""
        from repro.serving import Engine
        cfg, params = _smoke_model()
        draft = (cfg, params) if agreeing else _draft_model()
        ref = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=page_size)
        a = _results_by_rid(ref.serve(_mk_reqs(seed, n=5, max_new_hi=14)))
        spec = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=page_size, num_pages=pool,
                      spec_draft=draft, spec_k=spec_k)
        b = _results_by_rid(spec.serve(_mk_reqs(seed, n=5, max_new_hi=14)))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])

    def test_constructor_validation(self, setup):
        from repro.serving import Engine
        cfg, params = setup
        with pytest.raises(ValueError, match="paged"):
            Engine(cfg, params, spec_draft=(cfg, params))
        with pytest.raises(ValueError, match="spec_k"):
            Engine(cfg, params, paged=True, spec_draft=(cfg, params),
                   spec_k=0)
        with pytest.raises(ValueError, match="tokenizer"):
            Engine(cfg, params, paged=True,
                   spec_draft=(cfg.replace(vocab_size=17), params))

    def test_spec_engine_is_greedy_only(self, setup):
        from repro.serving import Engine, GenRequest
        cfg, params = setup
        spec = Engine(cfg, params, paged=True, spec_draft=(cfg, params))
        with pytest.raises(ValueError, match="greedy"):
            spec.submit(GenRequest(rid="t", tokens=np.arange(2, 8, dtype=np.int32),
                                   max_new=4, temperature=0.7))


# ---------------------------------------------------------------------------
# 3. multi-token emission semantics
# ---------------------------------------------------------------------------

class TestMultiTokenEmission:
    def test_eos_inside_accepted_run_truncates_identically(self, setup):
        """Pick an EOS id the model actually emits mid-stream, so the EOS
        lands inside an accepted draft run: the spec engine must truncate
        exactly like the single-token paged engine."""
        from repro.serving import Engine
        cfg, params = setup
        probe = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                       page_size=16)
        emitted = _results_by_rid(probe.serve(_mk_reqs(3, max_new_hi=10)))
        # an output token seen at position >= 2 of some request becomes EOS
        eos = None
        for toks in emitted.values():
            if len(toks) >= 3:
                eos = int(toks[2])
                break
        assert eos is not None
        cfg2 = cfg.replace(eos_id=eos)
        ref = Engine(cfg2, params, max_batch=4, bucket=16, paged=True,
                     page_size=16)
        a = _results_by_rid(ref.serve(_mk_reqs(3, max_new_hi=10)))
        spec = Engine(cfg2, params, max_batch=4, bucket=16, paged=True,
                      page_size=16, spec_draft=(cfg2, params), spec_k=3)
        b = _results_by_rid(spec.serve(_mk_reqs(3, max_new_hi=10)))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        # the EOS actually truncated something below its budget
        assert any(len(v) < r.max_new for v, r in
                   zip(a.values(), _mk_reqs(3, max_new_hi=10)))

    def test_budgets_never_exceeded(self, setup):
        """Multi-token acceptance must stop at max_new even when more
        drafts matched."""
        from repro.serving import Engine
        cfg, params = setup
        spec = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                      page_size=16, spec_draft=(cfg, params), spec_k=4)
        reqs = _mk_reqs(13, n=4, max_new_hi=7)
        done = spec.serve(reqs)
        for r in done:
            assert len(r.result) <= r.max_new


# ---------------------------------------------------------------------------
# 4. sim-vs-engine agreement
# ---------------------------------------------------------------------------

class TestSimEngineSpecAgreement:
    def test_boot_expected_tokens_agree(self, setup):
        """The engine's EMA is seeded from the sim's SPEC_ALPHA0 constant,
        so a fresh sim node and a fresh engine node report the same
        expected_tokens_per_step to dispatch."""
        from repro.serving import Engine, SpecEngineExecutor
        cfg, params = setup
        k = 3
        sim = _Harness(PROF, spec_k=k)
        ex = SpecEngineExecutor(Engine(cfg, params, max_batch=2, bucket=16,
                                       paged=True, page_size=16,
                                       spec_draft=(cfg, params), spec_k=k))
        ex.bind(None, lambda r, s, f: None)
        assert (ex.load().expected_tokens_per_step
                == sim.ex.load().expected_tokens_per_step
                == pytest.approx(spec_expected_tokens(SPEC_ALPHA0, k)))

    def test_admission_decisions_agree_on_identical_page_budget(self, setup):
        """Same admit/deny sequence as the paged agreement test: the spec
        executors inherit the page-granular rule (paged_admit_ok)
        unchanged — speculation changes drain rate, not residency."""
        from repro.serving import Engine, GenRequest, SpecEngineExecutor
        cfg, params = setup
        page, pool = 16, 8
        prof = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=page * pool)
        sim = _Harness(prof, page_size=page, spec_k=1)
        eng = Engine(cfg, params, max_batch=8, bucket=16, paged=True,
                     page_size=page, num_pages=pool,
                     spec_draft=(cfg, params), spec_k=1)
        ex = SpecEngineExecutor(eng, gate_on_pages=True)
        ex.bind(None, lambda r, st_, ft: None)
        rng = np.random.default_rng(5)
        sim_dec, eng_dec = [], []
        for i, plen in enumerate((40, 30, 50, 20)):     # pages 3, 2, 4, 2
            sim_dec.append(sim.ex.admit(_qr(f"s{i}", plen, 64)))
            ok = ex.admit(GenRequest(
                rid=f"e{i}", tokens=rng.integers(2, 400, size=plen)
                .astype(np.int32), max_new=64))
            eng_dec.append(ok)
            if ok:
                ex.step()         # prefill claims the prompt pages for real
        assert sim_dec == eng_dec == [True, True, False, True]
        assert ex.load().pages_total == sim.ex.load().pages_total == pool

    def test_engine_estimate_includes_draft_wall(self, setup):
        """SpecEngineExecutor.estimate charges the draft's measured wall
        time next to the target-side decode wall."""
        from repro.serving import Engine, SpecEngineExecutor
        cfg, params = setup
        ex = SpecEngineExecutor(Engine(cfg, params, max_batch=2, bucket=16,
                                       paged=True, page_size=16,
                                       spec_draft=(cfg, params), spec_k=2))
        ex.bind(None, lambda r, s, f: None)
        assert ex.estimate(64, 64) == float("inf")     # uncalibrated
        for r in _mk_reqs(21, n=2, max_new_hi=6):
            assert ex.admit(r)
        ex.drain()
        st = ex.engine.stats
        assert st.draft_wall_s > 0 and st.verify_wall_s > 0
        est = ex.estimate(64, 64)
        assert np.isfinite(est) and est > 0
        # target-only rate would promise a faster (smaller) time
        target_only = 64 / (st.decode_tokens / st.decode_wall_s) \
            + 64 / (st.prefill_tokens / st.prefill_wall_s)
        assert est >= target_only


# ---------------------------------------------------------------------------
# 5. acceptance-aware dispatch
# ---------------------------------------------------------------------------

class TestAcceptanceAwareDispatch:
    def _net(self, spec_nodes=("n2",), alpha=0.9):
        from repro.core import DuelParams
        net = Network(mode="decentralized", seed=0, init_balance=100.0,
                      power_of_two=True, duel=DuelParams(p_d=0.0))
        pol = NodePolicy(accept_freq=1.0, target_utilization=100.0)
        small = BackendProfile(prefill_tps=1e4, decode_tps=100.0,
                               saturation=2, max_concurrency=8, quality=0.5,
                               kv_token_budget=2048)
        for nid in ("n0", "n1", "n2"):
            if nid in spec_nodes:
                factory = (lambda node: SpecTokenBucketExecutor(
                    node.profile, spec_alpha=alpha))
            else:
                factory = (lambda node: TokenBucketExecutor(node.profile))
            net.add_node(Node(nid, small, policy=pol,
                              executor_factory=factory))
        return net

    def test_decode_pressure_discounted_by_acceptance_model(self):
        """Equal KV occupancy, but the spec node's decode backlog drains
        E[tokens/step] times faster — decode-heavy requests must see it as
        less pressured, prompt-heavy requests as equally pressured."""
        net = self._net()
        n1, n2 = net.nodes["n1"], net.nodes["n2"]
        for n in (n1, n2):
            assert n.executor.admit(_qr(f"fill-{n.id}", 24, 1000))
        net.loop.run(until=1.0)           # both streams are decoding now
        decode_heavy = Request(rid="d", origin="n0", arrival=1.0,
                               prompt_tokens=8, output_tokens=900,
                               slo_s=600.0)
        assert (net._phase_pressure(n2, decode_heavy)
                < net._phase_pressure(n1, decode_heavy))
        prompt_heavy = Request(rid="p", origin="n0", arrival=1.0,
                               prompt_tokens=4000, output_tokens=1,
                               slo_s=600.0)
        # ~all-prefill mix: the discount applies only to the (negligible)
        # decode share, so both nodes score essentially the same
        assert net._phase_pressure(n2, prompt_heavy) == pytest.approx(
            net._phase_pressure(n1, prompt_heavy), rel=1e-2)

    def test_est_wait_scales_decode_capacity(self):
        """The centralized estimator sees a spec node's backlog draining
        faster on identical queues."""
        net = self._net()
        n1, n2 = net.nodes["n1"], net.nodes["n2"]
        for n in (n1, n2):
            assert n.executor.admit(_qr(f"fill-{n.id}", 24, 1500))
        net.loop.run(until=1.0)
        req = Request(rid="x", origin="n0", arrival=1.0, prompt_tokens=8,
                      output_tokens=400, slo_s=600.0)
        assert net._est_wait(n2, req) < net._est_wait(n1, req)

    def test_decode_heavy_request_chases_spec_node(self):
        """Power-of-two probing with equal occupancy routes the
        decode-heavy request to the speculation-enabled candidate."""
        net = self._net()
        n1, n2 = net.nodes["n1"], net.nodes["n2"]
        for n in (n1, n2):
            assert n.executor.admit(_qr(f"fill-{n.id}", 24, 1000))
        net.loop.run(until=1.0)
        before = n2.executor.load().active_streams
        req = Request(rid="x", origin="n0", arrival=1.0, prompt_tokens=8,
                      output_tokens=900, slo_s=600.0)
        assert net.try_offload(net.nodes["n0"], req)
        net.loop.run(until=2.0)
        assert n2.executor.load().active_streams > before
