"""Compat layer: version-gate hygiene, both mesh backends, hypothesis shim.

Three families of tests:

1.  Hygiene guards proving that no module outside ``repro.compat``
    resolves version-gated ``jax.sharding`` / pallas symbols, and that
    the executor-layer state boundaries hold.  These are now thin
    wrappers over the AST checkers in ``repro.analysis`` (DESIGN.md §7)
    — the historical test names stay so the contract's history stays
    greppable, while the string greps they once were (with their
    docstring false positives and whole-file allowlists) are gone.
2.  Unit tests for ``repro.compat.meshenv`` exercising BOTH the modern
    (>=0.5, simulated via monkeypatching) and legacy (0.4.x) code paths,
    whichever JAX is actually installed.
3.  Determinism/correctness tests for the vendored hypothesis shim.
"""

import pathlib
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import run_analysis
from repro.compat import hypothesis_shim as shim
from repro.compat import meshenv

REPO = pathlib.Path(__file__).resolve().parents[1]


def _new_findings(rule):
    """New findings for one (sub-)rule over this repo, baseline disabled
    so grandfathering can never mask a regression in tier-1."""
    report = run_analysis(REPO, rules=[rule.split("/")[0]],
                          baseline_path="")
    return [f.format() for f in report.new
            if f.rule_id == rule or rule == f.rule_id.split("/")[0]]


# ---------------------------------------------------------------------------
# 1. version-gate hygiene
# ---------------------------------------------------------------------------

class TestVersionGateHygiene:
    def test_no_version_gated_symbols_outside_compat(self):
        offenders = _new_findings("compat-boundary")
        assert not offenders, (
            "version-gated mesh/pallas symbols outside repro.compat "
            "(route through meshenv/pallascompat instead):\n  "
            + "\n  ".join(offenders))


class TestExecutorLayerHygiene:
    """Frozen-share scheduling must not creep back: only the executor layer
    may call the analytic ``BackendProfile.service_time`` directly.  Nodes
    route execution through ``Executor.admit``/``load``/``estimate``
    (DESIGN.md §6.1)."""

    def test_service_time_only_called_from_executor_layer(self):
        offenders = _new_findings("layering/service-time")
        assert not offenders, (
            "direct service_time calls outside the executor layer "
            "(route through Executor.admit/load/estimate instead):\n  "
            + "\n  ".join(offenders))

    # the paged engine's page-pool bookkeeping is private to the engine;
    # everything else reads Engine.load_snapshot() / Executor.load()
    # (pages_used / pages_total / free_pages / page_size)
    def test_page_pool_state_private_to_engine(self):
        offenders = _new_findings("layering/private-state")
        assert not offenders, (
            "private page-pool state accessed outside the paged engine "
            "(read Engine.load_snapshot()/Executor.load() instead):\n  "
            + "\n  ".join(offenders))


class TestBenchSchema:
    """BENCH_scheduling.json drift is caught in tier-1: the checked-in
    artifact must satisfy the pinned schema that ``benchmarks/run.py
    --bench`` also validates at write time."""

    def test_checked_in_bench_matches_schema(self):
        import json

        from benchmarks.run import check_bench_schema
        path = REPO / "BENCH_scheduling.json"
        assert path.exists(), "BENCH_scheduling.json missing (run --bench)"
        payload = json.loads(path.read_text())
        check_bench_schema(payload)

    def test_schema_checker_rejects_drift(self):
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        del payload["engine"]["paged"]["decode_tokens_per_s"]
        with pytest.raises(AssertionError):
            check_bench_schema(payload)

    def test_schema_checker_rejects_mix_drift(self):
        """Schema 6 keeps pinning the disagg-vs-colocated mixed-workload
        section (incl. the surfaced transfer pipeline depth)."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        assert payload["schema"] == 9
        assert "ttft_speedup_prompt_heavy" in payload["mix"]
        for key in ("handoffs", "transfer_inflight_peak"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["mix"]["disagg"][key]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        del broken["mix"]["slot"]["avg_ttft_prompt_heavy_s"]
        with pytest.raises(AssertionError):
            check_bench_schema(broken)

    def test_schema_checker_rejects_kernel_drift(self):
        """Schema 6 pins the kernel microbench: slot/paged/quantized-paged
        timings, the autotuned pages_per_step, and the int8 admission demo
        whose >= 2x concurrency bar is a hard assert — a capacity
        regression in the quantized page pool fails tier-1, not just the
        artifact diff."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        kern = payload["kernel"]
        assert kern["admission"]["paged_quant"] >= 2 * kern["admission"]["paged"]
        assert kern["tuning"]["pages_per_step"] >= 1
        for key in ("slot", "paged", "paged_quant"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["kernel"]["decode"][key]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        del broken["kernel"]["spec_verify"]["paged_quant"]
        with pytest.raises(AssertionError):
            check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["kernel"]["admission"]["paged_quant"] = \
            2 * broken["kernel"]["admission"]["paged"] - 1
        with pytest.raises(AssertionError):
            check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["mix"]["paged"]["decode_tokens_per_s"] = \
            broken["mix"]["slot"]["decode_tokens_per_s"] - 1.0
        with pytest.raises(AssertionError):
            check_bench_schema(broken)

    def test_schema_checker_rejects_lint_drift(self):
        """Schema 6 pins the static-analysis snapshot: rule list, counts
        by disposition, and a hard zero on new violations — a baseline
        or suppression creep shows up in the artifact diff."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        assert payload["lint"]["new"] == 0
        assert payload["lint"]["rules"], "no checkers ran?"
        for key in ("rules", "suppressed", "baselined", "wall_s"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["lint"][key]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["lint"]["new"] = 3
        with pytest.raises(AssertionError):
            check_bench_schema(broken)

    def test_schema_checker_rejects_spec_drift(self):
        """Schema 6 pins the speculative-vs-paged decode-heavy section:
        accepted-length distribution + effective decode tokens/s."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        spec = payload["spec"]
        assert "speedup_decode_tokens_per_s" in spec
        assert len(spec["spec"]["accept_hist"]) == spec["spec_k"] + 1
        for key in ("accept_hist", "alpha_ema", "expected_tokens_per_step"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["spec"]["spec"][key]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        del broken["spec"]["paged"]["decode_tokens_per_s"]
        with pytest.raises(AssertionError):
            check_bench_schema(broken)

    def test_schema_checker_rejects_prefix_cache_drift(self):
        """Schema 8 pins the prefix-cache section (DESIGN.md §6.1-prefix):
        engine cached-vs-cold TTFT, the simulated zipf hit rate, and the
        affinity-vs-blind routing comparison — with hard bars (cached TTFT
        strictly below cold, hit rate >= 0.5, affinity above blind) so a
        cache regression fails tier-1, not just the artifact diff."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        pc = payload["prefix_cache"]
        assert pc["engine"]["cached_ttft_s"] < pc["engine"]["cold_ttft_s"]
        assert pc["sim"]["hit_rate"] >= 0.5
        assert (pc["routing"]["affinity"]["hit_rate"]
                > pc["routing"]["blind"]["hit_rate"])
        for key in ("cold_ttft_s", "cached_ttft_s", "ttft_speedup",
                    "hit_tokens", "cached_pages"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["prefix_cache"]["engine"][key]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        for mode in ("affinity", "blind"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["prefix_cache"]["routing"][mode]["hit_rate"]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        # hard-bar violations are rejected, not just missing keys
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["prefix_cache"]["engine"]["cached_ttft_s"] = \
            broken["prefix_cache"]["engine"]["cold_ttft_s"] + 1.0
        with pytest.raises(AssertionError):
            check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["prefix_cache"]["sim"]["hit_rate"] = 0.3
        with pytest.raises(AssertionError):
            check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["prefix_cache"]["routing"]["affinity"]["hit_rate"] = \
            broken["prefix_cache"]["routing"]["blind"]["hit_rate"]
        with pytest.raises(AssertionError):
            check_bench_schema(broken)

    def test_schema_checker_rejects_obs_drift(self):
        """Schema 9 pins the tracing-overhead section (DESIGN.md
        §Observability): mix decode tok/s with the tracer on vs off, with
        a hard >= 0.95x bound — making spans expensive fails tier-1, not
        just the artifact diff."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        obs = payload["obs"]
        assert obs["overhead_ratio"] >= 0.95
        assert obs["spans"] > 0
        for key in ("untraced", "traced", "overhead_ratio", "spans",
                    "metrics"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["obs"][key]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        for arm in ("untraced", "traced"):
            broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
            del broken["obs"][arm]["decode_tokens_per_s"]
            with pytest.raises(AssertionError):
                check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["obs"]["overhead_ratio"] = 0.8
        with pytest.raises(AssertionError):
            check_bench_schema(broken)

    def test_schema_checker_rejects_gossip_drift(self):
        """Schema 7 pins the gossip scale-out section (DESIGN.md
        §6.2-gossip): both routing modes at the 100- and 1k-node points,
        plus hard bars — at 1k nodes the digest plane must route with at
        least 3x fewer messages per request than the power-of-two probe
        baseline while holding SLO attainment within 2 points."""
        import json

        from benchmarks.run import check_bench_schema
        payload = json.loads((REPO / "BENCH_scheduling.json").read_text())
        gos = payload["gossip"]
        big = gos["points"]["1000"]
        assert (big["gossip"]["routing_msgs_per_req"]
                < big["probe"]["routing_msgs_per_req"])
        assert big["msgs_ratio"] >= 3.0
        assert big["slo_gap"] <= 0.02
        for pt in ("100", "1000"):
            for mode in ("gossip", "probe"):
                broken = json.loads(
                    (REPO / "BENCH_scheduling.json").read_text())
                del broken["gossip"]["points"][pt][mode]["routing_msgs_per_req"]
                with pytest.raises(AssertionError):
                    check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["gossip"]["points"]["1000"]["msgs_ratio"] = 2.5
        with pytest.raises(AssertionError):
            check_bench_schema(broken)
        broken = json.loads((REPO / "BENCH_scheduling.json").read_text())
        broken["gossip"]["points"]["1000"]["slo_gap"] = 0.1
        with pytest.raises(AssertionError):
            check_bench_schema(broken)


# ---------------------------------------------------------------------------
# 2. meshenv — legacy (0.4.x) path
# ---------------------------------------------------------------------------

def _force_legacy(monkeypatch):
    monkeypatch.delattr(jax.sharding, "get_abstract_mesh", raising=False)


class TestMeshEnvLegacy:
    def test_no_mesh_is_none(self, monkeypatch):
        _force_legacy(monkeypatch)
        assert meshenv.current_mesh() is None
        assert meshenv.axis_names() == ()
        assert meshenv.axis_sizes() == {}

    def test_mesh_context_sets_ambient_mesh(self, monkeypatch):
        _force_legacy(monkeypatch)
        m = meshenv.make_mesh((1, 1), ("data", "model"))
        with meshenv.mesh_context(m):
            got = meshenv.current_mesh()
            assert got is not None
            assert tuple(got.axis_names) == ("data", "model")
            assert meshenv.axis_sizes() == {"data": 1, "model": 1}
        assert meshenv.current_mesh() is None

    def test_with_sharding_constraint_under_jit(self, monkeypatch):
        _force_legacy(monkeypatch)
        m = meshenv.make_mesh((1, 1), ("data", "model"))
        x = jnp.arange(16.0).reshape(4, 4)
        with meshenv.mesh_context(m):
            y = jax.jit(lambda a: meshenv.with_sharding_constraint(
                a, P("data", None)))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_constraint_is_noop_without_mesh(self, monkeypatch):
        _force_legacy(monkeypatch)
        x = jnp.ones((2, 2))
        assert meshenv.with_sharding_constraint(x, P(None, None)) is x

    def test_shard_map_runs(self, monkeypatch):
        _force_legacy(monkeypatch)
        m = meshenv.make_mesh((1, 1), ("data", "model"))
        f = meshenv.shard_map(lambda a: a * 2, mesh=m,
                              in_specs=P(None, None),
                              out_specs=P(None, None))
        np.testing.assert_array_equal(np.asarray(f(jnp.ones((2, 2)))),
                                      2 * np.ones((2, 2)))

    def test_logical_spec_resolution(self, monkeypatch):
        """models.common routes through meshenv: batch -> present axes."""
        _force_legacy(monkeypatch)
        from repro.models import common as cm
        m = meshenv.make_mesh((1, 1), ("data", "model"))
        with meshenv.mesh_context(m):
            assert cm.logical("batch", None, "model") == \
                P(("data",), None, "model")
            assert cm.logical("absent_axis") == P(None)
        assert cm.logical("batch") == P(None)     # unmeshed: everything drops


# ---------------------------------------------------------------------------
# 2b. meshenv — modern (>=0.5) path, simulated
# ---------------------------------------------------------------------------

class _FakeAbstractMesh:
    def __init__(self, sizes, empty=False):
        self._sizes = dict(sizes)
        self.empty = empty

    @property
    def axis_names(self):
        return tuple(self._sizes)

    @property
    def shape(self):
        return dict(self._sizes)


class _FakeAxisType:
    Auto = "auto"


class TestMeshEnvModern:
    def _install(self, monkeypatch, mesh):
        monkeypatch.setattr(jax.sharding, "get_abstract_mesh",
                            lambda: mesh, raising=False)
        monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType,
                            raising=False)

    def test_modern_detection_and_current_mesh(self, monkeypatch):
        fake = _FakeAbstractMesh({"data": 2, "model": 4})
        self._install(monkeypatch, fake)
        assert meshenv.modern_api()
        assert meshenv.current_mesh() is fake
        assert meshenv.axis_names() == ("data", "model")
        assert meshenv.axis_sizes() == {"data": 2, "model": 4}
        assert meshenv.mesh_size(fake, ("data", "model")) == 8
        assert meshenv.mesh_size(fake, "model") == 4

    def test_empty_abstract_mesh_is_none(self, monkeypatch):
        self._install(monkeypatch, _FakeAbstractMesh({}, empty=True))
        assert meshenv.current_mesh() is None
        assert meshenv.axis_names() == ()

    def test_mesh_context_prefers_use_mesh(self, monkeypatch):
        """use_mesh is always a context manager; it must win over set_mesh
        even when both exist (set_mesh is a plain setter in some versions)."""
        self._install(monkeypatch, _FakeAbstractMesh({}, empty=True))
        events = []

        @__import__("contextlib").contextmanager
        def fake_use_mesh(m):
            events.append(("enter", m))
            yield
            events.append(("exit", m))

        monkeypatch.setattr(jax.sharding, "use_mesh", fake_use_mesh,
                            raising=False)
        monkeypatch.setattr(
            jax.sharding, "set_mesh",
            lambda m: events.append(("set", m)), raising=False)
        with meshenv.mesh_context("M"):
            pass
        assert events == [("enter", "M"), ("exit", "M")]

    def test_mesh_context_set_mesh_plain_setter(self, monkeypatch):
        """set_mesh variants that just set a global (returning the previous
        mesh, not a context manager) must still enter/restore correctly."""
        self._install(monkeypatch, _FakeAbstractMesh({}, empty=True))
        monkeypatch.delattr(jax.sharding, "use_mesh", raising=False)
        state = {"mesh": "OLD"}

        def fake_set_mesh(m):
            prev, state["mesh"] = state["mesh"], m
            return prev

        monkeypatch.setattr(jax.sharding, "set_mesh", fake_set_mesh,
                            raising=False)
        with meshenv.mesh_context("NEW"):
            assert state["mesh"] == "NEW"
        assert state["mesh"] == "OLD"

    def test_legacy_entry_found_despite_modern_probe(self, monkeypatch):
        """API window with get_abstract_mesh but no set_mesh/use_mesh:
        mesh_context enters via ``with mesh:`` and current_mesh must still
        discover it (legacy fallback behind the empty modern probe)."""
        self._install(monkeypatch, _FakeAbstractMesh({}, empty=True))
        monkeypatch.delattr(jax.sharding, "set_mesh", raising=False)
        monkeypatch.delattr(jax.sharding, "use_mesh", raising=False)
        m = meshenv.make_mesh((1, 1), ("data", "model"))
        with meshenv.mesh_context(m):
            got = meshenv.current_mesh()
            assert got is not None
            assert tuple(got.axis_names) == ("data", "model")
        assert meshenv.current_mesh() is None

    def test_make_mesh_passes_axis_types(self, monkeypatch):
        seen = {}

        def fake_make_mesh(shapes, names, **kw):
            seen.update(kw, shapes=shapes, names=names)
            return "mesh"

        monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
        monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType,
                            raising=False)
        assert meshenv.make_mesh((2, 2), ("data", "model")) == "mesh"
        assert seen["axis_types"] == ("auto", "auto")

    def test_make_mesh_retries_without_axis_types(self, monkeypatch):
        """AxisType present but make_mesh predating the kwarg (or the
        legacy API entirely): the builder must fall back cleanly."""
        calls = []

        def fake_make_mesh(shapes, names, **kw):
            calls.append(kw)
            if "axis_types" in kw:
                raise TypeError("unexpected keyword argument 'axis_types'")
            return "legacy-mesh"

        monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
        monkeypatch.setattr(jax.sharding, "AxisType", _FakeAxisType,
                            raising=False)
        assert meshenv.make_mesh((1, 1), ("data", "model")) == "legacy-mesh"
        assert len(calls) == 2 and "axis_types" not in calls[1]

    def test_modern_constraint_uses_bare_spec(self, monkeypatch):
        """With an (abstract, non-concrete) mesh active, the constraint is
        passed through as a bare PartitionSpec — the modern contract."""
        fake = _FakeAbstractMesh({"data": 1, "model": 1})
        self._install(monkeypatch, fake)
        captured = {}

        def fake_wsc(x, sharding):
            captured["sharding"] = sharding
            return x

        monkeypatch.setattr(jax.lax, "with_sharding_constraint", fake_wsc)
        x = jnp.ones((2, 2))
        meshenv.with_sharding_constraint(x, P("data", None))
        assert captured["sharding"] == P("data", None)
        assert not isinstance(captured["sharding"], Mesh)


# ---------------------------------------------------------------------------
# 3. hypothesis shim
# ---------------------------------------------------------------------------

class TestHypothesisShim:
    def test_draws_are_deterministic_across_runs(self):
        runs = []
        for _ in range(2):
            seen = []

            @shim.given(shim.strategies.integers(0, 1000),
                        f=shim.strategies.floats(0.0, 1.0))
            @shim.settings(max_examples=10, deadline=None)
            def prop(n, f):
                seen.append((n, f))

            prop()
            runs.append(list(seen))
        assert runs[0] == runs[1]
        assert len(runs[0]) == 10

    def test_strategy_bounds(self):
        rng = random.Random(0)
        st = shim.strategies
        for _ in range(200):
            assert 3 <= st.integers(3, 7).draw(rng) <= 7
            assert 0.25 <= st.floats(0.25, 0.75).draw(rng) <= 0.75
            assert st.sampled_from(["a", "b"]).draw(rng) in ("a", "b")
            lst = st.lists(st.integers(0, 1), min_size=2,
                           max_size=5).draw(rng)
            assert 2 <= len(lst) <= 5
            assert isinstance(st.booleans().draw(rng), bool)

    def test_composite_and_settings(self):
        st = shim.strategies
        calls = []

        @st.composite
        def pairs(draw):
            a = draw(st.integers(0, 10))
            return (a, draw(st.sampled_from([a, -a])))

        @shim.given(pairs())
        @shim.settings(max_examples=7, deadline=None)
        def prop(p):
            calls.append(p)
            assert abs(p[1]) == p[0]

        prop()
        assert len(calls) == 7

    def test_failure_reports_falsifying_example(self):
        @shim.given(shim.strategies.integers(5, 9))
        @shim.settings(max_examples=3, deadline=None)
        def prop(n):
            assert n < 5

        with pytest.raises(AssertionError, match="falsifying example"):
            prop()

    def test_methods_are_supported(self):
        """@given on a method must thread ``self`` through untouched."""
        outer = self

        class Holder:
            @shim.given(shim.strategies.integers(1, 3))
            @shim.settings(max_examples=4, deadline=None)
            def check(self, n):
                assert outer is not None
                assert 1 <= n <= 3

        Holder().check()
