"""Pure-jnp oracles for the Pallas kernels (also the CPU/dry-run path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (decode_attention as decode_ref,
                                    flash_attention as flash_ref,
                                    reference_attention,
                                    verify_attention as verify_ref)


def paged_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Oracle for block-table paged decode attention.

    q: (B, 1, H, D); k_pool/v_pool: (P, page, Hkv, D) — a shared pool of
    fixed-size KV pages; block_tables: (B, maxp) int32 mapping each row's
    logical page index to a physical page (entries past a row's allocation
    may point anywhere — typically the scratch page 0 — and are masked out
    by ``lengths``); lengths: (B,) int32 valid-token counts per row.

    Gathers each row's pages into a contiguous (B, maxp*page, Hkv, D) view
    and defers to the dense per-row-length decode oracle.  Returns
    (B, 1, H, D).
    """
    b, maxp = block_tables.shape
    page, hkv, d = k_pool.shape[1:]
    k = k_pool[block_tables].reshape(b, maxp * page, hkv, d)
    v = v_pool[block_tables].reshape(b, maxp * page, hkv, d)
    return decode_ref(q, k, v, lengths)


def paged_verify_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Oracle for block-table multi-token verify attention (speculative
    decoding, DESIGN.md §6.1-spec).

    q: (B, K, H, D) — K new tokens per row whose KV has already been
    scattered into the pool at positions ``lengths[b] .. lengths[b]+K-1``;
    pools/block_tables as in :func:`paged_decode_ref`; lengths: (B,) int32
    valid tokens per row BEFORE the K new tokens.  Query j attends
    positions ``<= lengths[b] + j`` (causal among the new tokens).

    Gathers each row's pages into a contiguous view and defers to the
    dense multi-token verify oracle.  Returns (B, K, H, D).
    """
    b, maxp = block_tables.shape
    page, hkv, d = k_pool.shape[1:]
    k = k_pool[block_tables].reshape(b, maxp * page, hkv, d)
    v = v_pool[block_tables].reshape(b, maxp * page, hkv, d)
    return verify_ref(q, k, v, lengths)


__all__ = ["decode_ref", "flash_ref", "reference_attention",
           "paged_decode_ref", "paged_verify_ref", "verify_ref"]
