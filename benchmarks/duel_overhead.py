"""Fig 7 + §7.1: duel-and-judge overhead at duel rates 5/10/25%.

Four nodes, k = 2 judges per duel, requests from a dedicated requester-only
node (intentionally higher relative overhead than typical deployments).
Checks (i) the analytic extra-load formula N·α·p_d·(1+k) against the
simulated count and (ii) that latency CDF / SLO curves stay nearly identical
across duel rates.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.core.duel import expected_extra_requests
from repro.sim import WorkloadSpec, make_profile, make_requests, uniform_phases

T_END = 900.0


def run_duel_rate(p_d: float, seed: int = 0) -> Dict:
    net = Network(mode="decentralized", seed=seed, ledger_mode="shared",
                  duel=DuelParams(p_d=p_d, k_judges=2), init_balance=500.0)
    req_pol = NodePolicy(offload_freq=1.0, accept_freq=0.0,
                         offload_queue_threshold=0,
                         offload_util_threshold=0.0, stake=1.0)
    net.add_node(Node("requester", make_profile(quality=0.5), policy=req_pol))
    for i in range(4):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.6),
                          policy=NodePolicy(offload_freq=0.0, accept_freq=1.0,
                                            target_utilization=0.9)))
    specs = [WorkloadSpec("requester", uniform_phases(T_END, 1.5),
                          output_mean=2048, slo_s=480.0)]
    m = net.run(make_requests(specs, seed=3 + seed), until=T_END)
    user = [c for c in m.completed if not c.is_duel_extra]
    extra = [c for c in m.completed if c.is_duel_extra]
    alpha = m.delegation_rate()
    return {
        "p_d": p_d,
        "slo": m.slo_attainment(),
        "avg_latency": m.avg_latency(),
        "p50": m.latency_percentile(50),
        "p90": m.latency_percentile(90),
        "n_user": len(user),
        "n_extra": len(extra),
        "predicted_extra": expected_extra_requests(len(user), alpha, p_d, 2),
    }


def main(rows: List[str]) -> None:
    base = None
    for p_d in (0.05, 0.10, 0.25):
        t0 = time.perf_counter()
        r = run_duel_rate(p_d)
        us = (time.perf_counter() - t0) * 1e6
        if base is None:
            base = r
        rel = r["avg_latency"] / base["avg_latency"]
        pred_ok = (abs(r["n_extra"] - r["predicted_extra"])
                   <= max(0.5 * r["predicted_extra"], 20))
        rows.append(
            f"fig7_duel_rate_{int(p_d*100)}pct,{us:.0f},"
            f"slo={r['slo']:.3f};lat={r['avg_latency']:.1f};p90={r['p90']:.1f};"
            f"extra={r['n_extra']};predicted={r['predicted_extra']:.0f};"
            f"formula_ok={pred_ok};lat_vs_5pct={rel:.3f}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
