"""SLO attainment, latency statistics, and windowed traces."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class CompletedRequest:
    rid: str
    origin: str
    executor: str
    arrival: float
    finish: float
    slo_s: float
    delegated: bool
    is_duel_extra: bool = False
    ttft: float = float("nan")        # arrival -> first output token
    queue_wait: float = float("nan")  # enqueue at executor -> admission

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def slo_met(self) -> bool:
        return self.latency <= self.slo_s


@dataclass
class MetricsCollector:
    completed: List[CompletedRequest] = field(default_factory=list)

    def record(self, c: CompletedRequest) -> None:
        self.completed.append(c)

    # -- aggregates (user traffic only; duel challengers/judges excluded) ----
    def _user(self) -> List[CompletedRequest]:
        return [c for c in self.completed if not c.is_duel_extra]

    def slo_attainment(self, scale: float = 1.0) -> float:
        """Fraction of user requests finishing within scale*slo threshold."""
        user = self._user()
        if not user:
            return 0.0
        return float(np.mean([c.latency <= scale * c.slo_s for c in user]))

    def slo_curve(self, scales: Sequence[float]) -> List[Tuple[float, float]]:
        """SLO-attainment vs threshold-scale curve (paper Fig 4 x-axis)."""
        return [(s, self.slo_attainment(s)) for s in scales]

    def avg_latency(self) -> float:
        user = self._user()
        return float(np.mean([c.latency for c in user])) if user else float("nan")

    def latency_percentile(self, p: float) -> float:
        user = self._user()
        return float(np.percentile([c.latency for c in user], p)) if user else float("nan")

    def latency_cdf(self, n: int = 200) -> List[Tuple[float, float]]:
        lats = np.sort([c.latency for c in self._user()])
        if lats.size == 0:
            return []
        qs = np.linspace(0, 1, n)
        return list(zip(np.quantile(lats, qs).tolist(), qs.tolist()))

    def windowed_latency(self, window: float, t_end: float) -> List[Tuple[float, float]]:
        """Windowed average latency by finish time (paper Fig 5 black line)."""
        out = []
        for t0 in np.arange(0.0, t_end, window):
            w = [c.latency for c in self._user() if t0 <= c.finish < t0 + window]
            if w:
                out.append((t0 + window / 2, float(np.mean(w))))
        return out

    def avg_ttft(self) -> float:
        vals = [c.ttft for c in self._user() if np.isfinite(c.ttft)]
        return float(np.mean(vals)) if vals else float("nan")

    def ttft_percentile(self, p: float) -> float:
        vals = [c.ttft for c in self._user() if np.isfinite(c.ttft)]
        return float(np.percentile(vals, p)) if vals else float("nan")

    def avg_queue_wait(self) -> float:
        vals = [c.queue_wait for c in self._user() if np.isfinite(c.queue_wait)]
        return float(np.mean(vals)) if vals else float("nan")

    def delegation_rate(self) -> float:
        user = self._user()
        return float(np.mean([c.delegated for c in user])) if user else 0.0

    def per_executor_counts(self, user_only: bool = True) -> Dict[str, int]:
        """Completions per executing node.  Like every other aggregate
        here this defaults to USER traffic — duel challengers/judges used
        to be counted too, which overstated duel-heavy nodes' share.
        ``user_only=False`` restores the raw count for duel accounting."""
        out: Dict[str, int] = {}
        for c in (self._user() if user_only else self.completed):
            out[c.executor] = out.get(c.executor, 0) + 1
        return out
