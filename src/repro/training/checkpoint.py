"""Msgpack + raw-numpy checkpointing (no orbax in this container)."""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


def save(path: str, tree, step: int = 0) -> None:
    flat, _ = _flatten(tree)
    payload = {
        "step": step,
        "leaves": {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                       "data": v.tobytes()} for k, v in flat.items()},
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)       # atomic


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays/structs)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        rec = payload["leaves"][f"leaf_{i}"]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"expected {tuple(ref.shape)}")
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), payload["step"]
