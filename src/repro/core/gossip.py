"""Gossip-driven peer synchronization (paper §A.2, Figure 10).

Every node keeps a local view: node_id -> PeerRecord(version, online, addr,
last_seen).  In each gossip round a node exchanges its full view with a few
random peers; each side keeps, per entry, the record with the higher
*version* (a per-origin monotonic counter bumped by the origin on any status /
address change, and by heartbeats).  Offline detection: if an entry's
heartbeat has not advanced within ``suspect_after`` sim-seconds, the node
locally marks the peer offline (the mark itself gossips as a higher-version
record only once the origin really stops heartbeating — a revived origin's
own heartbeat always wins because it carries a newer version).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class PeerRecord:
    node_id: str
    version: int
    online: bool
    addr: str
    heartbeat_time: float    # origin-local time of the last self-update


class PeerView:
    """One node's local membership view."""

    def __init__(self, self_id: str, addr: str, now: float = 0.0) -> None:
        self.self_id = self_id
        self.records: Dict[str, PeerRecord] = {
            self_id: PeerRecord(self_id, 1, True, addr, now)
        }

    # -- local mutations (the origin bumps its own version) ------------------
    def heartbeat(self, now: float) -> None:
        r = self.records[self.self_id]
        self.records[self.self_id] = replace(r, version=r.version + 1,
                                             heartbeat_time=now, online=True)

    def set_offline(self, now: float) -> None:
        r = self.records[self.self_id]
        self.records[self.self_id] = replace(r, version=r.version + 1,
                                             online=False, heartbeat_time=now)

    def set_addr(self, addr: str, now: float) -> None:
        r = self.records[self.self_id]
        self.records[self.self_id] = replace(r, version=r.version + 1,
                                             addr=addr, heartbeat_time=now)

    # -- anti-entropy merge ---------------------------------------------------
    def merge(self, remote: Iterable[PeerRecord]) -> int:
        """Keep the higher-version record per node. Returns #updates taken."""
        taken = 0
        for rec in remote:
            mine = self.records.get(rec.node_id)
            if mine is None or rec.version > mine.version:
                self.records[rec.node_id] = rec
                taken += 1
        return taken

    def suspect_failures(self, now: float, suspect_after: float) -> List[str]:
        """Locally mark peers offline whose heartbeat is stale."""
        newly = []
        for nid, rec in list(self.records.items()):
            if nid == self.self_id or not rec.online:
                continue
            if now - rec.heartbeat_time > suspect_after:
                # local suspicion does NOT bump version: a live origin's next
                # heartbeat (higher version) overrides it on merge.
                self.records[nid] = replace(rec, online=False)
                newly.append(nid)
        return newly

    def online_peers(self) -> List[str]:
        return sorted(n for n, r in self.records.items()
                      if r.online and n != self.self_id)

    def knows(self, nid: str) -> bool:
        return nid in self.records

    def snapshot(self) -> List[PeerRecord]:
        return list(self.records.values())


def gossip_round(a: PeerView, b: PeerView) -> Tuple[int, int]:
    """Symmetric pairwise exchange (paper Fig 10). Returns updates taken by each."""
    snap_a, snap_b = a.snapshot(), b.snapshot()
    return a.merge(snap_b), b.merge(snap_a)


def rounds_to_convergence(views: Sequence[PeerView], rng: np.random.Generator,
                          fanout: int = 2, max_rounds: int = 64) -> int:
    """Drive random pairwise gossip until all views agree; returns #rounds."""
    def converged() -> bool:
        base = {n: (r.version, r.online) for n, r in views[0].records.items()}
        return all({n: (r.version, r.online) for n, r in v.records.items()} == base
                   for v in views[1:])

    for rnd in range(1, max_rounds + 1):
        for v in views:
            peers = [w for w in views if w is not v]
            for w in rng.choice(len(peers), size=min(fanout, len(peers)),
                                replace=False):
                gossip_round(v, peers[int(w)])
        if converged():
            return rnd
    return max_rounds
