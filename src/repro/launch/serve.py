"""End-to-end decentralized serving driver (the paper's system, for real).

Spins up N WWW.Serve nodes, each backed by a REAL JAX engine serving a small
model; users submit batched requests to hot nodes; the decentralized protocol
(PoS routing, credit ledger, duels judged by sequence log-likelihood under
the judges' own models) redistributes them.  The protocol's executor
assignments are then replayed on real slot-based continuous-batching engines
behind the ``EngineExecutor`` interface (DESIGN.md §6.1): all engines are
pumped step-by-step in round-robin, so admissions interleave with decode
exactly as they would under live traffic, and per-node load is reported from
``Executor.load()`` snapshots.

    PYTHONPATH=src python -m repro.launch.serve --nodes 4 --requests 24
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import get_config
from repro.core import DuelParams, Network, Node, NodePolicy
from repro.models import registry
from repro.obs import (Tracer, breakdown_report, set_tracer,
                       write_chrome_trace)
from repro.serving import (DisaggEngineExecutor, Engine, EngineExecutor,
                           GenRequest, SpecEngineExecutor)
from repro.sim import make_profile
from repro.sim.workload import Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--duel-rate", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="back nodes with paged-KV engines "
                         "(DESIGN.md §6.1, paged backend)")
    ap.add_argument("--disagg", action="store_true",
                    help="back nodes with disaggregated prefill/decode "
                         "engine pairs joined by page-granular KV handoff "
                         "(DESIGN.md §6.1-disagg; implies paged)")
    ap.add_argument("--spec", action="store_true",
                    help="back nodes with speculative-decoding engines: a "
                         "tiny draft proposes --spec-k tokens per target "
                         "verify forward (DESIGN.md §6.1-spec; implies "
                         "paged)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify step")
    ap.add_argument("--kv-quant", action="store_true",
                    help="store KV pages as int8 with per-token-per-head "
                         "scale pools — half the bytes per resident token, "
                         "so the same HBM budget admits ~2x the concurrent "
                         "requests (DESIGN.md §6.1-paged; implies paged)")
    ap.add_argument("--trace", metavar="PATH",
                    help="record lifecycle spans (DESIGN.md §Observability) "
                         "for the protocol sim AND the real-engine replay, "
                         "write a Perfetto/Chrome trace_event JSON to PATH, "
                         "and print the per-request latency breakdown")
    args = ap.parse_args(argv)
    if args.spec and args.disagg:
        ap.error("--spec and --disagg are separate backends; pick one")
    if args.kv_quant and args.disagg:
        ap.error("--kv-quant is colocated-only: KV handoffs carry fp "
                 "pages (DESIGN.md §6.1-paged)")

    cfg = get_config(args.arch).smoke().replace(dtype="float32")
    if args.kv_quant:
        cfg = cfg.replace(kv_quant=True)
    print(f"spinning up {args.nodes} nodes serving {cfg.name}")
    rng = np.random.default_rng(args.seed)
    draft_cfg = draft_params = None
    if args.spec:
        # one shared draft model across nodes (a tiny same-tokenizer
        # sibling; in a real deployment each node brings its own)
        draft_cfg = cfg.draft()
        draft_params = registry.init(jax.random.PRNGKey(10_000), draft_cfg)

    net = Network(mode="decentralized", seed=args.seed,
                  duel=DuelParams(p_d=args.duel_rate, k_judges=1),
                  init_balance=100.0)
    executors: Dict[str, EngineExecutor] = {}
    for i in range(args.nodes):
        nid = f"node{i+1}"
        # heterogeneous quality: deeper-trained nodes get lower-temperature
        # params (stand-in for better models)
        params = registry.init(jax.random.PRNGKey(i), cfg)
        if args.disagg:
            executors[nid] = DisaggEngineExecutor(
                Engine(cfg, params, max_batch=4, bucket=32, seed=i,
                       paged=True),
                Engine(cfg, params, max_batch=4, bucket=32, seed=1000 + i,
                       paged=True))
        elif args.spec:
            executors[nid] = SpecEngineExecutor(
                Engine(cfg, params, max_batch=4, bucket=32, seed=i,
                       paged=True, spec_draft=(draft_cfg, draft_params),
                       spec_k=args.spec_k))
        else:
            executors[nid] = EngineExecutor(
                Engine(cfg, params, max_batch=4, bucket=32, seed=i,
                       paged=args.paged or args.kv_quant))
        executors[nid].owner = nid     # real-engine spans carry the node id
        prof = make_profile("qwen3-8b", "RTX3090", "sglang",
                            quality=0.4 + 0.15 * i)
        pol = NodePolicy(offload_util_threshold=0.15,
                         offload_queue_threshold=0, target_utilization=0.9)
        net.add_node(Node(nid, prof, policy=pol))

    # with --trace, both the protocol sim (sim clock) and the real-engine
    # replay (wall clock) record spans into one stream; the exporter maps
    # the two clock domains onto separate Perfetto processes
    old_tracer = set_tracer(Tracer()) if args.trace else None

    # submit all user requests to node1 (the hot node)
    t_wall = time.time()
    prompts = [rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
               for _ in range(args.requests)]
    sim_reqs = [Request(rid=f"r{i}", origin="node1", arrival=0.01 * i,
                        prompt_tokens=24, output_tokens=args.max_new,
                        slo_s=60.0) for i in range(args.requests)]
    m = net.run(sim_reqs, until=600.0)

    # replay the protocol's executor assignments on the real engines:
    # admit through the Executor interface, then pump all engines in
    # round-robin so slot admissions interleave with decode steps
    by_exec: Dict[str, List[int]] = {}
    for c in m.completed:
        if not c.is_duel_extra:
            by_exec.setdefault(c.executor, []).append(int(c.rid[1:]))
    print(f"protocol assigned: { {k: len(v) for k, v in by_exec.items()} }")
    done_by_node: Dict[str, List[GenRequest]] = {nid: [] for nid in by_exec}
    for nid, idxs in by_exec.items():
        ex = executors[nid]
        ex.bind(None, lambda r, st, ft, nid=nid:
                done_by_node[nid].append(r))
        for i in idxs:
            ex.admit(GenRequest(rid=f"r{i}", tokens=prompts[i],
                                max_new=args.max_new))
    busy = {nid for nid in by_exec if executors[nid].has_work()}
    while busy:
        for nid in sorted(busy):
            executors[nid].step()
        busy = {nid for nid in busy if executors[nid].has_work()}
    total_tokens = 0
    for nid in sorted(by_exec):
        ex, done = executors[nid], done_by_node[nid]
        ld, st = ex.load(), ex.engine_stats()
        total_tokens += sum(len(r.result) for r in done)
        disagg = (f", {st.handoffs} KV handoffs "
                  f"({st.handoff_bytes / 1e6:.1f} MB)"
                  if args.disagg else "")
        if args.spec:
            disagg = (f", spec accepted {st.spec_accepted}/{st.spec_drafted}"
                      f" drafts over {st.spec_steps} verifies "
                      f"(E[tok/step] {ld.expected_tokens_per_step:.2f})")
        print(f"  {nid}: served {len(done)} requests "
              f"({st.decode_tokens} decode tokens in "
              f"{st.decode_steps} steps; load: "
              f"{ld.active_streams} active / {ld.queued_streams} queued, "
              f"prefill headroom {ld.prefill_headroom:.2f}, "
              f"decode headroom {ld.decode_headroom:.2f}{disagg})")
    dt = time.time() - t_wall
    print(f"generated {total_tokens} tokens across {len(by_exec)} nodes "
          f"in {dt:.1f}s wall")
    print(f"sim SLO attainment: {m.slo_attainment():.3f}; "
          f"delegation rate: {m.delegation_rate():.2f}; "
          f"avg TTFT: {m.avg_ttft():.2f}s; "
          f"avg queue wait: {m.avg_queue_wait():.2f}s")
    print(f"credit balances: "
          f"{ {n: round(net.ledger_balance(n), 1) for n in net.nodes} }")
    if args.trace:
        tracer = set_tracer(old_tracer)
        payload = write_chrome_trace(tracer.spans, args.trace)
        print(breakdown_report(tracer.spans, limit=3))
        print(f"wrote {len(tracer.spans)} spans "
              f"({len(payload['traceEvents'])} events) to {args.trace}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
