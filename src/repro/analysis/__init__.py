"""repro.analysis: AST-based invariant linter for the repo's contracts.

One framework (``repro.analysis.framework``), six checkers
(DESIGN.md §7):

* ``compat-boundary`` — version-gated JAX symbols only via repro.compat
* ``layering``       — import DAG, Executor contract, state boundaries
* ``kernel-lint``    — Pallas kernel body / index-map / grid hygiene
* ``twin-drift``     — sim twin and engines share one constant vocabulary
* ``docs-anchors``   — DESIGN.md §-anchors resolve wherever cited
* ``obs-lint``       — spans and wall clocks go through repro.obs only

Run it as ``python -m repro.analysis`` (see ``__main__``), from tier-1
via ``tests/test_analysis.py``, or from ``benchmarks/run.py --lint``.
Stdlib-only by design: importing this package must never pull in jax.
"""

from repro.analysis.framework import (BASELINE_FILE, SCAN_DIRS, Checker,
                                      Finding, RepoIndex, Report,
                                      all_checkers, load_baseline,
                                      register, rule_matches, run_analysis,
                                      save_baseline)

# importing the checker modules is what populates the registry
from repro.analysis import compatrules as _compatrules    # noqa: F401
from repro.analysis import docanchors as _docanchors      # noqa: F401
from repro.analysis import kernellint as _kernellint      # noqa: F401
from repro.analysis import layering as _layering          # noqa: F401
from repro.analysis import obslint as _obslint            # noqa: F401
from repro.analysis import twindrift as _twindrift        # noqa: F401

__all__ = [
    "BASELINE_FILE", "SCAN_DIRS", "Checker", "Finding", "RepoIndex",
    "Report", "all_checkers", "load_baseline", "register", "rule_matches",
    "run_analysis", "save_baseline",
]
