"""Gossip-driven peer synchronization (paper §A.2, Figure 10) plus the
load-dissemination plane (DESIGN.md §6.2-gossip).

Every node keeps a local view: node_id -> PeerRecord(version, online, addr,
last_seen, digest).  In each gossip round a node exchanges its full view with
a few random peers; each side keeps, per entry, the record with the higher
*version* (a per-origin monotonic counter bumped by the origin on any status /
address change, and by heartbeats).

Two payloads ride the same versioned records:

* **Load digests** — each heartbeat carries a compact ``LoadDigest`` of the
  origin's ``ExecutorLoad`` (headrooms, phase backlogs, speculative speedup,
  cumulative handoff bytes, prefix-cache hit rate plus the fingerprints of
  its most-recently-touched resident prefixes for cache-affinity dispatch
  (DESIGN.md §6.1-prefix), snapshot timestamp).  Because the digest is
  versioned by the same per-origin counter, anti-entropy merging propagates
  the freshest digest for free; routers rank candidates from this stale
  table with staleness discounting instead of probing every candidate.
* **Dead reports** — when a peer's heartbeat goes stale past
  ``suspect_after``, the suspecting node marks it offline *at the suspected
  version*.  The merge rule treats offline-at-equal-version as newer, so
  the suspicion spreads epidemically until the whole view agrees
  (consensus), while a revived origin's own heartbeat — which always
  carries a strictly higher version — overrides the report everywhere it
  has spread.  A node that receives a dead report about *itself* refutes it
  by jumping its own version past the report's.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.sim.executor import LoadDigest


@dataclass(frozen=True)
class PeerRecord:
    node_id: str
    version: int
    online: bool
    addr: str
    heartbeat_time: float    # origin-local time of the last self-update
    digest: Optional[LoadDigest] = None   # load digest published at heartbeat


class PeerView:
    """One node's local membership view.

    ``view_cap`` bounds the number of *remote* records retained (partial
    views, HyParView-style): past the cap, merging evicts the records with
    the stalest heartbeats.  ``None`` (the default) keeps full membership —
    the cap only matters at the 10k-node scale where O(n) views per node
    stop being realistic.
    """

    def __init__(self, self_id: str, addr: str, now: float = 0.0,
                 view_cap: Optional[int] = None) -> None:
        self.self_id = self_id
        self.view_cap = view_cap
        self.records: Dict[str, PeerRecord] = {
            self_id: PeerRecord(self_id, 1, True, addr, now)
        }

    # -- local mutations (the origin bumps its own version) ------------------
    def heartbeat(self, now: float, digest: Optional[LoadDigest] = None) -> None:
        """Bump own version; piggyback a fresh load digest when given (a
        ``None`` digest keeps the previously published one)."""
        r = self.records[self.self_id]
        self.records[self.self_id] = replace(
            r, version=r.version + 1, heartbeat_time=now, online=True,
            digest=digest if digest is not None else r.digest)

    def set_offline(self, now: float) -> None:
        r = self.records[self.self_id]
        self.records[self.self_id] = replace(r, version=r.version + 1,
                                             online=False, heartbeat_time=now)

    def set_addr(self, addr: str, now: float) -> None:
        r = self.records[self.self_id]
        self.records[self.self_id] = replace(r, version=r.version + 1,
                                             addr=addr, heartbeat_time=now)

    # -- anti-entropy merge ---------------------------------------------------
    def merge(self, remote: Iterable[PeerRecord]) -> int:
        """Per node, keep the higher-version record; at *equal* version a
        dead report (offline) beats a live record, so suspicion propagates
        without stealing the origin's version counter.  Returns #updates
        taken."""
        taken = 0
        for rec in remote:
            mine = self.records.get(rec.node_id)
            if rec.node_id == self.self_id:
                assert mine is not None
                if mine.online and not rec.online and rec.version >= mine.version:
                    # dead report about myself: refute it by jumping past
                    # the report's version so the refutation wins merges.
                    self.records[self.self_id] = replace(
                        mine, version=rec.version + 1, online=True)
                    taken += 1
                continue
            if (mine is None or rec.version > mine.version
                    or (rec.version == mine.version
                        and mine.online and not rec.online)):
                self.records[rec.node_id] = rec
                taken += 1
        if taken:
            self._evict_over_cap()
        return taken

    def _evict_over_cap(self) -> None:
        cap = self.view_cap
        if cap is None:
            return
        extra = (len(self.records) - 1) - cap
        if extra <= 0:
            return
        stalest = sorted(
            (r.heartbeat_time, nid) for nid, r in self.records.items()
            if nid != self.self_id)[:extra]
        for _, nid in stalest:
            del self.records[nid]

    def suspect_failures(self, now: float, suspect_after: float) -> List[str]:
        """Mark peers offline whose heartbeat is stale.  The mark keeps the
        suspected version — the dead-at-equal-version merge rule then
        gossips it to consensus, while the origin's next heartbeat (a
        strictly higher version) revives it everywhere."""
        newly = []
        for nid, rec in list(self.records.items()):
            if nid == self.self_id or not rec.online:
                continue
            if now - rec.heartbeat_time > suspect_after:
                self.records[nid] = replace(rec, online=False)
                newly.append(nid)
        return newly

    def online_peers(self) -> List[str]:
        return sorted(n for n, r in self.records.items()
                      if r.online and n != self.self_id)

    def digest_of(self, nid: str) -> Optional[LoadDigest]:
        """Last gossip-learned load digest for ``nid`` (None = never seen)."""
        rec = self.records.get(nid)
        return rec.digest if rec is not None else None

    def knows(self, nid: str) -> bool:
        return nid in self.records

    def snapshot(self) -> List[PeerRecord]:
        return list(self.records.values())


def gossip_round(a: PeerView, b: PeerView) -> Tuple[int, int]:
    """Symmetric pairwise exchange (paper Fig 10). Returns updates taken by each."""
    snap_a, snap_b = a.snapshot(), b.snapshot()
    return a.merge(snap_b), b.merge(snap_a)


def rounds_to_convergence(views: Sequence[PeerView], rng: np.random.Generator,
                          fanout: int = 2, max_rounds: int = 64) -> int:
    """Drive random pairwise gossip until all views agree — including the
    digest payloads, so convergence means every node also holds the same
    load picture, not just the same membership.  Returns #rounds."""
    def state(v: PeerView) -> Dict[str, Tuple[int, bool, Optional[LoadDigest]]]:
        return {n: (r.version, r.online, r.digest) for n, r in v.records.items()}

    for rnd in range(1, max_rounds + 1):
        for v in views:
            peers = [w for w in views if w is not v]
            for w in rng.choice(len(peers), size=min(fanout, len(peers)),
                                replace=False):
                gossip_round(v, peers[int(w)])
        base = state(views[0])
        if all(state(v) == base for v in views[1:]):
            return rnd
    return max_rounds
