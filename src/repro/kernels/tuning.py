"""Kernel tuning registry + autotune sweep for the paged kernels.

The paged flash-decode and spec-verify kernels (DESIGN.md §Perf-kernels)
expose one tunable: **pages_per_step** — how many physical pages one grid
step DMAs and reduces.  More pages per step amortizes grid overhead and
lets the pager batch HBM->VMEM transfers; fewer keeps VMEM pressure down
for large ``page_size * head_dim`` blocks.  The right choice depends only
on the static shape triple ``(page_size, head_dim, n_kv_heads)``, so the
choice is recorded per-triple in a module-level registry that both kernel
wrappers consult when the caller does not pass ``pages_per_step``
explicitly.

``autotune_paged_decode`` is the sweep helper: it times the real kernel
(interpret mode off-TPU) over candidate values on caller-supplied arrays
and records the winner.  ``benchmarks/run.py --bench`` runs it at the
bench's pinned shapes and publishes the chosen tuning in the ``kernel``
section of ``BENCH_scheduling.json`` so the choice is tracked PR over PR.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterable, Tuple

import jax


@dataclass(frozen=True)
class KernelTuning:
    """Static kernel shape choices for one (page_size, head_dim, hkv)."""
    pages_per_step: int = 1


DEFAULT_TUNING = KernelTuning(pages_per_step=1)

_REGISTRY: Dict[Tuple[int, int, int], KernelTuning] = {}


def tuning_key(page_size: int, head_dim: int, hkv: int) -> Tuple[int, int, int]:
    return (int(page_size), int(head_dim), int(hkv))


def record_tuning(page_size: int, head_dim: int, hkv: int,
                  tuning: KernelTuning) -> None:
    _REGISTRY[tuning_key(page_size, head_dim, hkv)] = tuning


def tuning_for(page_size: int, head_dim: int, hkv: int) -> KernelTuning:
    """Recorded tuning for the shape triple, or the safe default."""
    return _REGISTRY.get(tuning_key(page_size, head_dim, hkv),
                         DEFAULT_TUNING)


def clear_tunings() -> None:
    """Reset the registry (test isolation)."""
    _REGISTRY.clear()


def autotune_paged_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                          block_tables: jax.Array, lengths: jax.Array, *,
                          candidates: Iterable[int] = (1, 2, 4),
                          iters: int = 3,
                          interpret: bool = True) -> KernelTuning:
    """Sweep ``pages_per_step`` candidates on real arrays, record + return
    the fastest.  The winner is keyed by ``(page_size, head_dim, hkv)`` so
    every later kernel call at this shape picks it up automatically.
    """
    # function-level import: the kernel wrapper consults this registry for
    # its default, so a module-level import would be circular
    from repro.kernels.paged_decode import flash_paged_decode_tpu

    page_size, hkv, d = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    best, best_t = DEFAULT_TUNING, float("inf")
    for pps in candidates:
        def run():
            return flash_paged_decode_tpu(
                q, k_pool, v_pool, block_tables, lengths,
                pages_per_step=pps, interpret=interpret)
        run().block_until_ready()              # warm / trace
        t0 = time.perf_counter()
        for _ in range(iters):
            run().block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        if dt < best_t:
            best, best_t = KernelTuning(pages_per_step=pps), dt
    record_tuning(page_size, d, hkv, best)
    return best


__all__ = ["KernelTuning", "DEFAULT_TUNING", "tuning_key", "record_tuning",
           "tuning_for", "clear_tunings", "autotune_paged_decode"]
