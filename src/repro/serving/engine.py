"""A small batched serving engine — the node's Model Manager backend.

Real (not simulated) JAX inference with **slot-based continuous batching**
(DESIGN.md §6.1): the engine keeps a persistent decode cache with
``max_batch`` row slots, each resident sequence decoding at its own depth
(per-row cache lengths).  After every decode step finished sequences are
evicted and queued requests are prefilled into the freed slots — a short
request no longer holds the batch hostage for the longest request's budget.
Prompts are right-padded, which causal attention keeps inert, so a request's
greedy output is independent of what it happens to be batched with (wave
batching, ``continuous=False``, produces bit-identical greedy results in
more decode steps).

This is the backend used by the runnable examples and the end-to-end
decentralized serving driver (``repro.launch.serve``, via
``repro.serving.executor.EngineExecutor``); the large-scale scheduling
benchmarks use the simulated executor instead (see DESIGN.md §6.1).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig
from repro.serving.sampling import sample


@dataclass
class GenRequest:
    rid: str
    tokens: np.ndarray            # (S,) prompt token ids
    max_new: int = 32
    temperature: float = 0.0
    result: Optional[np.ndarray] = None
    # engine metrics (wall-clock)
    enqueued_at: float = 0.0
    started_at: float = 0.0       # admitted into a slot (prefill)
    first_token_at: float = 0.0   # first output token sampled
    finished_at: float = 0.0


@dataclass
class EngineStats:
    served: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    batches: int = 0              # prefill batches
    decode_steps: int = 0         # batched decode_step invocations
    prefill_wall_s: float = 0.0   # wall time inside prefill calls
    decode_wall_s: float = 0.0    # wall time inside decode_step calls


class _Slot:
    """One resident sequence: its request, sampled tokens, cache depth."""

    __slots__ = ("req", "out")

    def __init__(self, req: GenRequest) -> None:
        self.req = req
        self.out: List[int] = []


class Engine:
    """Persistent-slot continuous batching with a jitted step per bucket."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 bucket: int = 64, seed: int = 0,
                 capacity: Optional[int] = None,
                 continuous: bool = True) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.continuous = continuous
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        fam = registry.get_family(cfg)
        # right-padding is only inert with a full cache: a sliding-window
        # ring keeps the last `window` positions of the PADDED sequence, so
        # trailing pads would evict real in-window KV — window configs stay
        # on the left-padded lock-step wave path
        self.slot_decode = fam.slot_decode and cfg.sliding_window is None
        if self.slot_decode:
            self._prefill = jax.jit(
                lambda p, b, cap, lp: fam.prefill(p, cfg, b, q_chunk=256,
                                                  kv_chunk=256, capacity=cap,
                                                  last_positions=lp),
                static_argnums=(2,))
        else:
            # families without per-row cache depths fall back to left-padded
            # lock-step wave batching
            self._prefill = jax.jit(
                lambda p, b, cap: fam.prefill(p, cfg, b, q_chunk=256,
                                              kv_chunk=256, capacity=cap),
                static_argnums=(2,))
        self._decode = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))
        self.eos_id = 1

        # persistent slot state
        self._queue: List[GenRequest] = []
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._lengths = np.zeros(max_batch, np.int64)   # per-row cache depth
        self._cache: Optional[Dict] = None
        self._logits: Optional[jax.Array] = None
        self._capacity = int(capacity or 0)

    def _pad_bucket(self, n: int) -> int:
        b = self.bucket
        return max(b, (n + b - 1) // b * b)

    def _required(self, r: GenRequest) -> int:
        return self._pad_bucket(len(r.tokens)) + self._pad_bucket(r.max_new)

    # ------------------------------------------------------------- interface
    def submit(self, r: GenRequest) -> None:
        r.enqueued_at = time.perf_counter()
        self._queue.append(r)

    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def active_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def queued(self) -> int:
        return len(self._queue)

    def load_snapshot(self) -> Dict[str, int]:
        """Occupancy counts for Executor.load() — the supported view of the
        slot/queue bookkeeping (token counts are *remaining* work)."""
        active = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        return dict(
            active_streams=len(active),
            queued_streams=len(self._queue),
            queued_prompt_tokens=sum(len(r.tokens) for r in self._queue),
            queued_new_tokens=sum(r.max_new for r in self._queue),
            pending_decode_tokens=sum(s.req.max_new - len(s.out)
                                      for _, s in active),
            kv_used=int(sum(self._lengths[i] + s.req.max_new - len(s.out)
                            for i, s in active)),
            kv_budget=self.max_batch * max(self._capacity, 1))

    def serve(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Submit ``reqs`` and pump steps until the engine drains."""
        if not self.slot_decode:
            return self._serve_wave_legacy(reqs)
        for r in reqs:
            self.submit(r)
        while self.has_work():
            self.step()
        return reqs

    def generate_batch(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Serve up to max_batch requests together; returns them completed."""
        assert len(reqs) <= self.max_batch
        return self.serve(reqs)

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        if resident and any(self._required(r) > self._capacity
                            for r in self._queue):
            # a queued request needs a bigger cache, which can only be
            # allocated while nothing is resident: stop backfilling so the
            # batch drains and the growth branch below runs (otherwise a
            # steady stream of small requests starves the big one forever)
            return
        if not resident:
            # grow the cache while nothing is resident (allocation is static
            # under jit, so capacity only changes between generations)
            needed = max(self._required(r)
                         for r in self._queue[:self.max_batch])
            if self._cache is None or needed > self._capacity:
                self._capacity = max(self._capacity, needed)
                self._cache = None
                self._logits = None
        free = [i for i, s in enumerate(self._slots) if s is None]
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        for r in self._queue:
            # skip requests the current cache can't hold; they are admitted
            # at the next idle point, when capacity can grow
            if free and self._required(r) <= self._capacity:
                take.append((free.pop(0), r))
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._prefill_into(take)

    def _prefill_into(self, take: List[Tuple[int, GenRequest]]) -> None:
        n = len(take)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        for j, (_, r) in enumerate(take):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      self._capacity, jnp.asarray(last))
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * n
        self.stats.batches += 1
        kv = {k: v for k, v in cache.items() if k != "length"}
        rows = jnp.asarray([i for i, _ in take])
        if self._cache is None:
            self._cache = jax.tree_util.tree_map(
                lambda leaf: jnp.zeros(
                    (leaf.shape[0], self.max_batch) + leaf.shape[2:],
                    leaf.dtype), kv)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._cache = jax.tree_util.tree_map(
            lambda p, nw: p.at[:, rows].set(nw), self._cache, kv)
        self._logits = self._logits.at[rows].set(logits)
        now = time.perf_counter()
        for i, r in take:
            r.started_at = now
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)

    # ------------------------------------------------------------ decode step
    def step(self) -> List[GenRequest]:
        """One engine iteration: sample a token for every resident sequence,
        retire finished ones, prefill admissions into freed slots, then run
        one batched decode step for the sequences that continue."""
        if not self.slot_decode:
            return self._step_wave_legacy()
        self._admit()
        resident = [i for i, s in enumerate(self._slots) if s is not None]
        if not resident:
            return []
        # 1. sample next token for all resident rows from their current logits
        self.key, sk = jax.random.split(self.key)
        temps_np = np.zeros(self.max_batch, np.float32)
        for i in resident:
            temps_np[i] = self._slots[i].req.temperature
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        cur = sample(sk, self._logits, temperature=temps,
                     vocab_size=self.cfg.vocab_size)
        cur_np = np.asarray(cur[:, 0])
        now = time.perf_counter()
        finished: List[GenRequest] = []
        survivors: List[int] = []
        for i in resident:
            slot = self._slots[i]
            slot.out.append(int(cur_np[i]))
            if len(slot.out) == 1:
                slot.req.first_token_at = now
            hit_eos = cur_np[i] == self.eos_id
            if hit_eos or len(slot.out) >= slot.req.max_new:
                row = slot.out[:-1] if hit_eos and len(slot.out) > 1 \
                    else slot.out
                slot.req.result = np.asarray(row, np.int32)
                slot.req.finished_at = now
                finished.append(slot.req)
                self._slots[i] = None
                self.stats.served += 1
            else:
                survivors.append(i)
        # 2. admit queued work into freed slots between decode steps
        if self.continuous and finished:
            self._admit()
        # 3. one batched decode step advances the surviving rows; rows that
        #    were empty or just prefilled ride along (static batch shape) —
        #    their cache write lands at their own depth and is overwritten by
        #    their first real decode, and their logits are kept, not replaced
        if survivors:
            cache = {**self._cache,
                     "length": jnp.asarray(self._lengths, jnp.int32)}
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, cur)
            logits.block_until_ready()
            self.stats.decode_wall_s += time.perf_counter() - t0
            self._cache = {k: v for k, v in cache.items() if k != "length"}
            keep = jnp.asarray(survivors)
            self._logits = self._logits.at[keep].set(logits[keep])
            self._lengths[survivors] += 1
            self.stats.decode_tokens += len(survivors)
            self.stats.decode_steps += 1
        return finished

    # ----------------------------------------------- legacy wave (non-dense)
    def _step_wave_legacy(self) -> List[GenRequest]:
        if not self._queue:
            return []
        wave, self._queue = (self._queue[: self.max_batch],
                             self._queue[self.max_batch:])
        return self._generate_wave(wave)

    def _serve_wave_legacy(self, reqs: List[GenRequest]) -> List[GenRequest]:
        out: List[GenRequest] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self._generate_wave(reqs[i: i + self.max_batch]))
        return out

    def _generate_wave(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Left-padded lock-step decode for families without per-row cache
        depths (shared scalar cache length)."""
        assert len(reqs) <= self.max_batch
        max_prompt = max(len(r.tokens) for r in reqs)
        plen = self._pad_bucket(max_prompt)
        max_new = max(r.max_new for r in reqs)
        toks = np.full((len(reqs), plen), self.eos_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.tokens):] = r.tokens     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cap = plen + self._pad_bucket(max_new)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cap)
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * len(reqs)
        self.stats.batches += 1
        for r in reqs:
            r.started_at = time.perf_counter()

        out = np.zeros((len(reqs), max_new), np.int32)
        done = np.zeros(len(reqs), bool)
        temps_np = np.array([r.temperature for r in reqs], np.float32)
        # all-greedy batches (the default) keep the scalar fast path in
        # sample(), skipping the per-step Gumbel draw over the vocab
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        budgets = np.array([r.max_new for r in reqs])
        for step in range(max_new):
            self.key, sk = jax.random.split(self.key)
            cur = sample(sk, logits, temperature=temps,
                         vocab_size=self.cfg.vocab_size)
            out[:, step] = np.asarray(cur[:, 0])
            if step == 0:
                now = time.perf_counter()
                for r in reqs:
                    r.first_token_at = now
            done |= out[:, step] == self.eos_id
            done |= step + 1 >= budgets
            if done.all():
                break
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, cur)
            logits.block_until_ready()
            self.stats.decode_wall_s += time.perf_counter() - t0
            self.stats.decode_tokens += int((~done).sum())
            self.stats.decode_steps += 1
        for i, r in enumerate(reqs):
            row = out[i, : r.max_new]
            end = np.argmax(row == self.eos_id) if (row ==
                                                    self.eos_id).any() \
                else r.max_new
            r.result = row[: max(int(end), 1)]
            r.finished_at = time.perf_counter()
        self.stats.served += len(reqs)
        return reqs

    def logprob_of(self, tokens: np.ndarray) -> float:
        """Sequence log-likelihood under this engine's model — used by the
        real-engine duel judges (DESIGN.md §6.2)."""
        t = jnp.asarray(tokens[None, :])
        logits = registry.apply_logits(self.params, self.cfg,
                                       {"tokens": t[:, :-1]},
                                       q_chunk=256, kv_chunk=256)
        logits = logits.astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(lp, t[:, 1:, None], axis=-1)
        return float(jnp.sum(gold))
