"""Memory-sane attention in pure jnp (flash-style chunked online softmax).

This is simultaneously (i) the attention used by every model in the zoo for
train / prefill lowering (O(chunk²) peak memory, so 32k prefill fits), and
(ii) the numerical oracle that the Pallas kernels in ``repro.kernels`` are
validated against.

Supports causal masking, GQA (n_kv_heads < n_heads), and sliding windows.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import meshenv

# shared masking constant: the Pallas kernels import this rather than
# re-defining it, so the oracle and the kernels cannot disagree on what
# "masked out" means (finite so exp() underflows cleanly, never NaN)
NEG_INF = -1e30


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,H,D) by repeating kv heads (GQA)."""
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    rep = n_heads // hkv
    return jnp.repeat(k, rep, axis=2)


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                   window: Optional[int]) -> jax.Array:
    """(Sq, Skv) boolean 'attend' mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        q_offset: int = 0) -> jax.Array:
    """Naive O(S²) attention — oracle for tests, small shapes only.

    q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D).  ``q_offset`` is the absolute position
    of q[0] (used at decode: Sq=1 at position Skv-1).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    mask = attention_mask(q_pos, k_pos, causal, window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    skip_masked_blocks: bool = False) -> jax.Array:
    """Chunked online-softmax attention, O(q_chunk·kv_chunk) score memory.

    q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D), Sq == Skv (train / prefill).
    ``skip_masked_blocks`` unrolls the q-chunk loop in Python and, per q
    chunk, only visits kv chunks intersecting the causal/window band —
    halving causal FLOPs (§Perf iteration; off = simplest baseline).
    """
    from repro.models import runtime
    if runtime.roofline_mode():
        # exact op counts require python-unrolled block loops + big chunks
        q_chunk = runtime.attn_chunk(q_chunk)
        kv_chunk = runtime.attn_chunk(kv_chunk)
        skip_masked_blocks = True
    b, sq_orig, h, d = q.shape
    skv_orig = k.shape[1]
    q_chunk = min(q_chunk, sq_orig)
    kv_chunk = min(kv_chunk, skv_orig)
    q_pad = (-sq_orig) % q_chunk
    kv_pad = (-skv_orig) % kv_chunk
    # pad to chunk multiples; padded keys sit at positions >= skv_orig and are
    # masked out below, padded queries are sliced off the output.
    if q_pad:
        q = jnp.pad(q, [(0, 0), (0, q_pad), (0, 0), (0, 0)])
    if kv_pad:
        kv_p = [(0, 0), (0, kv_pad), (0, 0), (0, 0)]
        k, v = jnp.pad(k, kv_p), jnp.pad(v, kv_p)
    sq, skv = sq_orig + q_pad, skv_orig + kv_pad
    nq, nk = sq // q_chunk, skv // kv_chunk
    scale = d ** -0.5

    from repro.models import runtime as _rt
    if _rt.gqa_native() and k.shape[2] < h:
        # §Perf variant: keep K/V at n_kv_heads — q grouped (Hkv, rep) — so
        # expanded KV copies never materialize (HBM traffic / memory term)
        hkv = k.shape[2]
        rep = h // hkv
        qg = q.reshape(b, sq, hkv, rep, d).transpose(0, 2, 3, 1, 4)
        qg = qg.reshape(b, hkv * rep, sq, d)   # grouped-head contiguous
        kr = k.transpose(0, 2, 1, 3).reshape(b, hkv, skv, d)
        vr = v.transpose(0, 2, 1, 3).reshape(b, hkv, skv, d)
        out = _flash_grouped(qg, kr, vr, rep, nq, nk, q_chunk, kv_chunk,
                             causal, window, skv_orig, scale,
                             skip_masked_blocks)
        out = (out.reshape(b, hkv, rep, sq, d).transpose(0, 3, 1, 2, 4)
               .reshape(b, sq, h, d))[:, :sq_orig]
        return out.astype(q.dtype)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    # (B, H, nq, qc, D) etc. — scan over chunk axes
    qr = q.transpose(0, 2, 1, 3).reshape(b, h, nq, q_chunk, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b, h, nk, kv_chunk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b, h, nk, kv_chunk, d)

    def one_q_chunk(qi: int, qc: jax.Array, kv_lo: int, kv_hi: int) -> jax.Array:
        """qc: (B,H,qc,D); visit kv chunks [kv_lo, kv_hi)."""
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, kj):
            acc, m, l = carry
            kc = jax.lax.dynamic_index_in_dim(kr, kj, 2, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vr, kj, 2, keepdims=False)
            sc = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                            kc.astype(jnp.float32)) * scale
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            mask = attention_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < skv_orig)[None, :]
            sc = jnp.where(mask[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
            return (acc, m_new, l), None

        init = (jnp.zeros((b, h, q_chunk, d), jnp.float32),
                jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, h, q_chunk), jnp.float32))
        if skip_masked_blocks:
            carry = init
            for kj in range(kv_lo, kv_hi):
                carry, _ = body(carry, kj)
            acc, m, l = carry
        else:
            (acc, m, l), _ = jax.lax.scan(body, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if skip_masked_blocks:
        outs = []
        for qi in range(nq):
            q_hi_pos = (qi + 1) * q_chunk - 1
            q_lo_pos = qi * q_chunk
            hi = (q_hi_pos // kv_chunk + 1) if causal else nk
            lo = 0
            if window is not None:
                lo = max(0, (q_lo_pos - window + 1) // kv_chunk)
            outs.append(one_q_chunk(qi, qr[:, :, qi], lo, min(hi, nk)))
        out = jnp.stack(outs, axis=2)                  # (B,H,nq,qc,D)
    else:
        out = jax.lax.map(
            lambda qi: one_q_chunk(qi, jax.lax.dynamic_index_in_dim(
                qr, qi, 2, keepdims=False), 0, nk),
            jnp.arange(nq))                            # (nq,B,H,qc,D)
        out = jnp.moveaxis(out, 0, 2)                  # (B,H,nq,qc,D)
    out = out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)[:, :sq_orig]
    return out.astype(q.dtype)


def kv_quantize(x: jax.Array):
    """Symmetric int8 per-(token, head) quantization of K/V.

    x: (..., D) -> (int8 values, bf16 scales (..., 1)).  Halves (vs bf16) the
    dominant decode HBM stream and cache residency; dequant is fused into the
    attention read on TPU.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def _flash_grouped(qg, kr, vr, rep, nq, nk, q_chunk, kv_chunk, causal,
                   window, skv_orig, scale, skip):
    """GQA-native chunked flash: qg (B, Hkv*rep, Sq, D) grouped by kv head;
    kr/vr (B, Hkv, Skv, D).  The rep query heads of a group share the kv
    tiles, so K/V are never expanded."""
    b, hr, sq, d = qg.shape
    hkv = kr.shape[1]
    qg = qg.reshape(b, hkv, rep, nq, q_chunk, d)
    krc = kr.reshape(b, hkv, nk, kv_chunk, d)
    vrc = vr.reshape(b, hkv, nk, kv_chunk, d)

    def one_q(qi: int, qc: jax.Array, lo: int, hi: int) -> jax.Array:
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        acc = jnp.zeros((b, hkv, rep, q_chunk, d), jnp.float32)
        m = jnp.full((b, hkv, rep, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, rep, q_chunk), jnp.float32)
        for kj in range(lo, hi):
            kc = krc[:, :, kj].astype(jnp.float32)
            vc = vrc[:, :, kj].astype(jnp.float32)
            sc = jnp.einsum("bgrqd,bgkd->bgrqk", qc.astype(jnp.float32),
                            kc) * scale
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            mask = attention_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < skv_orig)[None, :]
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sc - m_new[..., None])
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vc)
            m = m_new
        return acc / jnp.maximum(l, 1e-30)[..., None]

    outs = []
    for qi in range(nq):
        hi = ((qi + 1) * q_chunk - 1) // kv_chunk + 1 if causal else nk
        lo = 0
        if window is not None:
            lo = max(0, (qi * q_chunk - window + 1) // kv_chunk)
        outs.append(one_q(qi, qg[:, :, :, qi], lo, min(hi, nk)))
    out = jnp.stack(outs, axis=3)            # (B,Hkv,rep,nq,qc,D)
    return out.reshape(b, hkv, rep, sq, d).reshape(b, hkv * rep, sq, d)


def decode_attention_seqsharded(q: jax.Array, k_cache: jax.Array,
                                v_cache: jax.Array, k_new: jax.Array,
                                v_new: jax.Array, slot: jax.Array,
                                cache_len: jax.Array, *,
                                scales: Optional[tuple] = None):
    """§Perf variant: sequence-sharded flash-decode via shard_map, with the
    ring-cache write done LOCALLY by the owning shard.

    Baseline GSPMD turns the dynamic-update-slice into a seq-sharded cache
    into cache-sized collectives (the dominant decode collective in the
    roofline).  Here each shard (a) updates its own slice if the write slot
    falls in its range — zero communication — and (b) computes a partial
    attention output + log-sum-exp over its chunk; a pmax/psum of the tiny
    (B,1,H,D) partials combines them.  TPU analogue of flash-decode /
    tree-attention sequence parallelism.

    Returns (attn_out, new_k_cache, new_v_cache).
    """
    quant = scales is not None
    if quant:
        ks_cache, vs_cache, kn_scale, vn_scale = scales
    mesh = meshenv.current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        dus = lambda c, n: jax.lax.dynamic_update_slice(c, n, (0, slot, 0, 0))
        kc, vc = dus(k_cache, k_new), dus(v_cache, v_new)
        if quant:
            ks_c, vs_c = dus(ks_cache, kn_scale), dus(vs_cache, vn_scale)
            out = decode_attention(q, kv_dequantize(kc, ks_c, q.dtype),
                                   kv_dequantize(vc, vs_c, q.dtype),
                                   cache_len)
            return out, kc, vc, ks_c, vs_c
        return decode_attention(q, kc, vc, cache_len), kc, vc
    from jax.sharding import PartitionSpec as P
    from repro.models.common import BATCH_AXES
    batch_ax = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    b_spec = batch_ax if (batch_ax and q.shape[0] %
                          meshenv.mesh_size(mesh, batch_ax) == 0) else None
    h = q.shape[2]

    def local(q_l, k_l, v_l, kn, vn, scalars, *scl):
        # q_l/kn/vn: (B_l, 1, ·, D) replicated over model;
        # k_l/v_l: (B_l, S/m, Hkv, D) — this shard's seq chunk.
        slot_, n_valid = scalars[0], scalars[1]
        d = q_l.shape[-1]
        s_loc = k_l.shape[1]
        shard = jax.lax.axis_index("model")
        # (a) local ring write — no comms
        local_slot = slot_ - shard * s_loc
        in_range = (local_slot >= 0) & (local_slot < s_loc)
        safe = jnp.clip(local_slot, 0, s_loc - 1)

        def write(cache, new):
            upd = jax.lax.dynamic_update_slice(
                cache, new, (0, safe) + (0,) * (cache.ndim - 2))
            return jnp.where(in_range, upd, cache)

        k_l, v_l = write(k_l, kn), write(v_l, vn)
        if quant:
            ks_l, vs_l = write(scl[0], scl[2]), write(scl[1], scl[3])
            kf = kv_dequantize(k_l, ks_l, jnp.float32)
            vf = kv_dequantize(v_l, vs_l, jnp.float32)
        else:
            kf = k_l.astype(jnp.float32)
            vf = v_l.astype(jnp.float32)
        # (b) partial attention over the local chunk — GQA-native: K/V stay
        # at n_kv_heads, the rep query heads of a group share the kv stream
        bl = q_l.shape[0]
        hkv = k_l.shape[2]
        rep = h // hkv
        qg = q_l.reshape(bl, hkv, rep, d).astype(jnp.float32)
        sc = jnp.einsum("bgrd,bkgd->bgrk", qg, kf) / (d ** 0.5)
        pos = shard * s_loc + jnp.arange(s_loc)
        valid = pos[None, :] < jnp.reshape(n_valid, (-1, 1))
        sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
        m_loc = sc.max(-1)                                      # (B,Hkv,rep)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(sc - m_glob[..., None])
        l_loc = p.sum(-1)
        o_loc = jnp.einsum("bgrk,bkgd->bgrd", p, vf)
        l = jax.lax.psum(l_loc, "model")
        o = jax.lax.psum(o_loc, "model")
        out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(bl, 1, h, d)
        if quant:
            return out.astype(q_l.dtype), k_l, v_l, ks_l, vs_l
        return out.astype(q_l.dtype), k_l, v_l

    rep_spec = P(b_spec, None, None, None)
    seq_spec = P(b_spec, "model", None, None)
    in_specs = [rep_spec, seq_spec, seq_spec, rep_spec, rep_spec, P()]
    out_specs = [rep_spec, seq_spec, seq_spec]
    args = [q, k_cache, v_cache, k_new, v_new,
            jnp.stack([jnp.asarray(slot, jnp.int32),
                       jnp.asarray(cache_len, jnp.int32)])]
    if quant:
        in_specs += [seq_spec, seq_spec, rep_spec, rep_spec]
        out_specs += [seq_spec, seq_spec]
        args += [ks_cache, vs_cache, kn_scale, vn_scale]
    fn = meshenv.shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                           out_specs=tuple(out_specs), check_rep=False)
    return fn(*args)


def verify_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     base_len: jax.Array) -> jax.Array:
    """Multi-token verify attention (speculative decoding, DESIGN.md
    §6.1-spec): K query tokens appended to a cache of ``base_len`` valid
    positions, causally masked among themselves.

    q: (B,K,H,D); k_cache/v_cache: (B,S,Hkv,D) with the K new tokens'
    KV already written at positions ``base_len .. base_len+K-1``;
    base_len: () or (B,) int32.  Query j sits at absolute position
    ``base_len + j`` and attends positions ``<= base_len + j`` — with
    K == 1 this reduces exactly to ``decode_attention(q, k, v,
    base_len + 1)``.  Full attention only (the paged engine rejects
    sliding-window configs).
    """
    b, kq, h, d = q.shape
    s = k_cache.shape[1]
    k = _expand_kv(k_cache, h).astype(jnp.float32)
    v = _expand_kv(v_cache, h).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k) / (d ** 0.5)
    pos = jnp.arange(s)
    limit = jnp.reshape(base_len, (-1, 1)) + jnp.arange(kq)[None, :]  # (B,K)
    valid = pos[None, None, :] <= limit[..., None]            # (B,K,S)|(1,K,S)
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token decode attention over a (possibly partially filled) cache.

    q: (B,1,H,D); k_cache/v_cache: (B,S,Hkv,D); cache_len: () or (B,) int32 —
    number of valid positions (the query attends to positions < cache_len).
    For sliding-window caches S == window and all positions are valid.
    """
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    k = _expand_kv(k_cache, h).astype(jnp.float32)
    v = _expand_kv(v_cache, h).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k) / (d ** 0.5)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))          # (B,S)|(1,S)
    if window is not None:
        valid &= pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(q.dtype)
