from repro.serving.engine import Engine, EngineStats, GenRequest
from repro.serving.sampling import sample
