"""Architecture + input-shape registry for the assigned (arch × shape) grid."""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCHS: Dict[str, str] = {
    "starcoder2-7b": "starcoder2_7b",
    "qwen3-8b": "qwen3_8b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "dbrx-132b": "dbrx_132b",
    "qwen3-32b": "qwen3_32b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "command-r-plus-104b": "command_r_plus_104b",
    "whisper-base": "whisper_base",
}


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# sliding window applied when long_500k runs on a full-attention arch; a
# per-model config may override by defining LONG_CONTEXT_WINDOW itself
DEFAULT_LONG_CONTEXT_WINDOW = 4096


def get_config(arch: str, shape: Optional[str] = None) -> ModelConfig:
    """Resolve an architecture config; `long_500k` on a full-attention arch
    returns the sliding-window variant (see DESIGN.md §Arch-applicability)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    cfg: ModelConfig = mod.CONFIG
    if shape == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        window = getattr(mod, "LONG_CONTEXT_WINDOW",
                         DEFAULT_LONG_CONTEXT_WINDOW)
        cfg = cfg.replace(name=cfg.name + "-window",
                          sliding_window=window)
    return cfg


def list_archs() -> List[str]:
    return sorted(ARCHS)


def grid() -> List[Tuple[str, str]]:
    """All assigned (arch, shape) combinations — 10 × 4 = 40."""
    return [(a, s) for a in sorted(ARCHS) for s in INPUT_SHAPES]
