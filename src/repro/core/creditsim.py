"""Vectorized stochastic credit-dynamics simulator (pure JAX, lax.scan).

Monte-Carlo counterpart of ``gametheory.py``: at every step a batch of
delegated requests arrives; executors are PoS-sampled (Gumbel top-k over
log-stakes); a fraction p_d become duels whose winners follow Assumption 5.3's
pairwise win probability; credits are updated with base reward, cost, bonus
and penalty.  Whole trajectories are jit-compiled — thousands of steps for
hundreds of nodes run in milliseconds on CPU, which is what lets the
benchmarks sweep system parameters widely.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CreditSimParams(NamedTuple):
    q: jax.Array         # (N,) latent quality
    c: jax.Array         # (N,) per-request cost
    R: float = 1.0
    p_d: float = 0.1
    R_add: float = 0.5
    P: float = 0.5
    restake: float = 1.0  # fraction of net payoff flowing back into stake


def _pos_pick(key: jax.Array, stakes: jax.Array, n: int) -> jax.Array:
    """Sample ``n`` independent nodes ∝ stake (with replacement across draws)."""
    logits = jnp.log(jnp.maximum(stakes, 1e-9))
    return jax.random.categorical(key, logits, shape=(n,))


@functools.partial(jax.jit, static_argnames=("steps", "requests_per_step"))
def simulate(params: CreditSimParams, s0: jax.Array, key: jax.Array,
             steps: int = 500, requests_per_step: int = 32):
    """Returns (stake trajectory (steps, N), duel win counts, duel counts)."""
    n_nodes = s0.shape[0]
    m = requests_per_step

    def step(carry, key_t):
        stakes, wins, duels = carry
        k_exec, k_duel, k_pair, k_out = jax.random.split(key_t, 4)

        execs = _pos_pick(k_exec, stakes, m)                     # (m,)
        is_duel = jax.random.bernoulli(k_duel, params.p_d, (m,))
        rivals = _pos_pick(k_pair, stakes, m)                    # (m,)
        # duel win prob per Assumption 5.3's pairwise form
        p_win = jnp.clip(0.5 * (1.0 + params.q[execs] - params.q[rivals]), 0, 1)
        won = jax.random.bernoulli(k_out, p_win)

        base = params.R - params.c[execs]                        # (m,)
        duel_pay = jnp.where(won, params.R_add, -params.P)
        pay = base + jnp.where(is_duel, duel_pay, 0.0)
        # mirror payoff for the rival in a duel
        rival_pay = jnp.where(is_duel,
                              (params.R - params.c[rivals])
                              + jnp.where(won, -params.P, params.R_add), 0.0)

        d_stake = (jnp.zeros(n_nodes).at[execs].add(params.restake * pay)
                   .at[rivals].add(params.restake * rival_pay))
        stakes = jnp.maximum(stakes + d_stake, 1e-6)

        wins = wins.at[execs].add(jnp.where(is_duel & won, 1, 0))
        wins = wins.at[rivals].add(jnp.where(is_duel & ~won, 1, 0))
        duels = duels.at[execs].add(jnp.where(is_duel, 1, 0))
        duels = duels.at[rivals].add(jnp.where(is_duel, 1, 0))
        return (stakes, wins, duels), stakes

    keys = jax.random.split(key, steps)
    init = (s0, jnp.zeros(n_nodes, jnp.int32), jnp.zeros(n_nodes, jnp.int32))
    (stakes, wins, duels), traj = jax.lax.scan(step, init, keys)
    return traj, wins, duels
