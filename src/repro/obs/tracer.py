"""Lifecycle spans over two clock domains (DESIGN.md §Observability).

A :class:`Span` is one closed interval of a request's (or a batch step's)
life: a name from the span taxonomy, the request id it belongs to (empty
for batch-scoped engine spans), the node/executor that produced it, start
and end timestamps, and free-form JSON-able attributes.  Spans carry a
``clock`` tag because the repo runs on two different time bases that must
never be mixed: the discrete-event simulator's ``EventLoop.now`` (seconds
of *simulated* time, shared by ``core`` and ``sim``) and the process wall
clock (``time.perf_counter``, used by the real JAX engines in
``serving``).  The exporter keeps them apart as separate Perfetto
processes.

Two recording styles:

* **Explicit timestamps** (:meth:`Tracer.span` / :meth:`Tracer.event`)
  for the sim domain, where the caller already knows both endpoints from
  ``EventLoop.now`` and the request's stamped times.
* **Measured blocks** (:meth:`Tracer.wall`) for the serving domain: a
  context manager that ALWAYS measures ``perf_counter`` — its ``dt``
  feeds the ``EngineStats`` wall-time accumulators whether or not tracing
  is on — and appends a span only when the tracer is enabled.  This is
  the one sanctioned way to time a block in instrumented layers; the
  ``obs-lint/wall-clock`` rule (DESIGN.md §7) keeps raw
  ``time.perf_counter()`` calls from creeping back in.

``Span`` itself is constructed only inside ``repro.obs``
(``obs-lint/span-construction``, same pattern as the gossip
digest-construction rule): everything else goes through the ``Tracer``
API, so a disabled tracer really is a handful of attribute checks and
span streams stay well-formed.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

# clock domains
SIM = "sim"      # EventLoop.now — simulated seconds (core/sim layers)
WALL = "wall"    # time.perf_counter — process seconds (serving layer)


@dataclass
class Span:
    """One closed interval ``[t0, t1]`` of a request's lifecycle.

    ``rid`` is the request id ("" for batch-scoped engine spans), ``who``
    the node or executor that produced it.  ``t0 == t1`` marks an instant
    event (``executor.admit``, ``executor.preempt``), which the exporter
    renders as a Perfetto instant rather than a zero-width slice.
    """

    name: str
    rid: str
    who: str
    t0: float
    t1: float
    clock: str = SIM
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class Tracer:
    """Append-only span sink; ``enabled=False`` is a cheap no-op.

    The default process-wide tracer (``get_tracer()``) starts disabled,
    so instrumented code pays one truthiness check per would-be span.
    Drivers that want a trace either ``set_tracer(Tracer())`` for the
    scope of a run or pass an explicit tracer to the objects they build.
    """

    __slots__ = ("enabled", "spans")

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self.spans: List[Span] = []

    # ------------------------------------------------------------ recording
    def span(self, name: str, rid: str, who: str, t0: float, t1: float,
             clock: str = SIM, **attrs: Any) -> None:
        """Record a completed interval with explicit endpoints (the sim
        domain's style: both times come from ``EventLoop.now``)."""
        if self.enabled:
            self.spans.append(Span(name, rid, who, t0, t1, clock, attrs))

    def event(self, name: str, rid: str, who: str, t: float,
              clock: str = SIM, **attrs: Any) -> None:
        """Record an instant (``t0 == t1``): admissions, preemptions."""
        if self.enabled:
            self.spans.append(Span(name, rid, who, t, t, clock, attrs))

    def wall(self, name: str, rid: str = "", who: str = "",
             **attrs: Any) -> "WallSpan":
        """A measured wall-clock block (see :class:`WallSpan`)."""
        return WallSpan(self, name, rid, who, attrs)

    # ------------------------------------------------------------- reading
    def clear(self) -> None:
        self.spans.clear()

    def by_request(self) -> Dict[str, List[Span]]:
        """Spans grouped by request id (batch-scoped ``rid == ""`` spans
        excluded), each group sorted by start time."""
        out: Dict[str, List[Span]] = {}
        for s in self.spans:
            if s.rid:
                out.setdefault(s.rid, []).append(s)
        for spans in out.values():
            spans.sort(key=lambda s: (s.t0, s.t1))
        return out


class WallSpan:
    """Timed wall-clock block: always measures, records when enabled.

    The measurement is unconditional because the serving layer's
    ``EngineStats`` accumulators (``decode_wall_s`` etc.) are fed from
    ``dt`` and must keep working with tracing off; only the span append
    is gated on the tracer.  Hand-rolled (no ``contextlib``) to keep the
    per-decode-step overhead to two clock reads and one allocation.
    """

    __slots__ = ("_tracer", "_name", "_rid", "_who", "_attrs", "t0", "t1")

    def __init__(self, tracer: Tracer, name: str, rid: str, who: str,
                 attrs: Dict[str, Any]) -> None:
        self._tracer = tracer
        self._name = name
        self._rid = rid
        self._who = who
        self._attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0

    def __enter__(self) -> "WallSpan":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        self.t1 = time.perf_counter()
        t = self._tracer
        if t.enabled:
            t.spans.append(Span(self._name, self._rid, self._who,
                                self.t0, self.t1, WALL, self._attrs))
        return False

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-wide default tracer (disabled until a driver swaps in
    an enabled one); instrumented objects resolve it at construction when
    not handed an explicit tracer."""
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-wide default; returns the one it
    replaced so drivers can restore it."""
    global _TRACER
    old, _TRACER = _TRACER, tracer
    return old


def wall_now() -> float:
    """The sanctioned wall clock for instrumented layers: request
    timestamps (``enqueued_at``/``started_at``/...) are stamped through
    this so the ``obs-lint/wall-clock`` rule can hold the serving layer
    to a single auditable time base."""
    return time.perf_counter()
