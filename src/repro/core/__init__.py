"""WWW.Serve core: the paper's decentralized serving mechanisms."""

from repro.core.duel import DuelParams, DuelOutcome, expected_extra_requests, run_duel
from repro.core.gossip import PeerRecord, PeerView, gossip_round, rounds_to_convergence
from repro.core.ledger import (BalanceView, CreditBlock, CreditChain, CreditOp,
                               LedgerError, SharedLedger)
from repro.core.network import Network, TREASURY
from repro.core.node import Node, QueuedRequest
from repro.core.policy import NodePolicy
from repro.core.pos import pos_sample, pos_sample_one, selection_probs

__all__ = [
    "DuelParams", "DuelOutcome", "expected_extra_requests", "run_duel",
    "PeerRecord", "PeerView", "gossip_round", "rounds_to_convergence",
    "BalanceView", "CreditBlock", "CreditChain", "CreditOp", "LedgerError",
    "SharedLedger", "Network", "TREASURY", "Node", "QueuedRequest",
    "NodePolicy", "pos_sample", "pos_sample_one", "selection_probs",
]
