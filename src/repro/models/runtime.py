"""Lowering-mode switches for the dry-run roofline analysis.

XLA's ``cost_analysis`` counts a while-loop body ONCE, so a scan-over-layers
model under-reports FLOPs/bytes by the trip count.  For the ROOFLINE lowering
we therefore unroll the structural loops (layer scans, attention block loops,
mLSTM chunk scans) so the compiled artifact's op counts are exact; the FIT
lowering (memory analysis, multi-pod proof) keeps the production scan
structure.  ``roofline_mode()`` is consulted at every scan site.

The one loop that cannot be unrolled at 4k+ steps is the sLSTM time scan
(true sequential dependence).  In roofline mode it is replaced by a
flops-equivalent parallel surrogate: identical matmul/elementwise op counts
per timestep, recurrent inputs taken from the (precomputed) input stream
instead of h_{t-1}.  This changes VALUES, never op counts — and the roofline
only reads op counts.  Documented in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar

_ROOFLINE: ContextVar[bool] = ContextVar("repro_roofline_mode", default=False)


def roofline_mode() -> bool:
    return _ROOFLINE.get()


@contextlib.contextmanager
def roofline_lowering():
    tok = _ROOFLINE.set(True)
    try:
        yield
    finally:
        _ROOFLINE.reset(tok)


def scan_unroll():
    """unroll parameter for structural lax.scans."""
    return True if _ROOFLINE.get() else 1


def attn_chunk(default: int) -> int:
    """Bigger attention chunks in roofline mode keep the unrolled block count
    small (the block loop is python-unrolled there)."""
    return 4096 if _ROOFLINE.get() else default


# ---------------------------------------------------------------------------
# §Perf hillclimb variants (EXPERIMENTS.md): beyond-paper sharding options.
# ---------------------------------------------------------------------------

_SEQ_PARALLEL: ContextVar[bool] = ContextVar("repro_seq_parallel",
                                             default=False)
_DECODE_SEQ_SHARD: ContextVar[bool] = ContextVar("repro_decode_seq_shard",
                                                 default=False)
_ATTN_BATCH_ONLY: ContextVar[bool] = ContextVar("repro_attn_batch_only",
                                                default=False)
_GQA_NATIVE: ContextVar[bool] = ContextVar("repro_gqa_native", default=False)
_MOE_A2A: ContextVar[bool] = ContextVar("repro_moe_a2a", default=False)


def moe_a2a() -> bool:
    """Explicit expert-parallel all-to-all MoE dispatch (see moe.py)."""
    return _MOE_A2A.get()


def gqa_native() -> bool:
    """GQA-native flash attention: K/V stay at n_kv_heads (no expanded
    copies) — the rep query heads of a group share kv tiles."""
    return _GQA_NATIVE.get()


def seq_parallel() -> bool:
    """Megatron-style sequence parallelism: residual activations sharded over
    'model' along the sequence dim (reduce-scatter/all-gather replace the TP
    all-reduces, and per-device activation memory drops by the TP degree)."""
    return _SEQ_PARALLEL.get()


def decode_seq_shard() -> bool:
    """shard_map flash-decode: KV sequence-sharded over 'model' with an
    explicit log-sum-exp combine (psum of (B,H,dh) partials) instead of
    whatever GSPMD infers for the sharded softmax."""
    return _DECODE_SEQ_SHARD.get()


def attn_batch_only() -> bool:
    """Skip the 'model' constraint on q/k/v projections (attention data-
    parallel only) — for head counts that don't divide the model axis."""
    return _ATTN_BATCH_ONLY.get()


@contextlib.contextmanager
def perf_flags(seq_parallel_: bool = False, decode_seq_shard_: bool = False,
               attn_batch_only_: bool = False, gqa_native_: bool = False,
               moe_a2a_: bool = False):
    pairs = [(_SEQ_PARALLEL, seq_parallel_),
             (_DECODE_SEQ_SHARD, decode_seq_shard_),
             (_ATTN_BATCH_ONLY, attn_batch_only_),
             (_GQA_NATIVE, gqa_native_),
             (_MOE_A2A, moe_a2a_)]
    toks = [(var, var.set(val)) for var, val in pairs]
    try:
        yield
    finally:
        for var, tok in toks:
            var.reset(tok)
