"""Version/environment compatibility layer.

``repro.compat.meshenv`` is the single point of contact for every
mesh/sharding introspection the model and launch stacks perform:
axis discovery, ambient-mesh queries, mesh construction, sharding
constraints, and shard_map.  No module outside this package may touch a
version-gated ``jax.sharding`` symbol (``get_abstract_mesh``, ``AxisType``,
``set_mesh``/``use_mesh``, ``axis_types=``) — enforced by
``tests/test_compat.py``.

``repro.compat.hypothesis_shim`` is a minimal deterministic stand-in for
the ``hypothesis`` property-testing API, used by the root ``conftest.py``
when the real package is not installed (offline containers).
"""

from repro.compat import meshenv

__all__ = ["meshenv"]
