"""Protocol-level artifacts:

* ledger ablation (paper §C): full Credit Block Chain vs shared-ledger fast
  path — identical balances, measured bookkeeping overhead (the paper chose
  the shared ledger at experiment scale for exactly this reason);
* gossip convergence (paper §A.2 'converge quickly'): anti-entropy rounds to
  full agreement vs network size, expected O(log N).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.core.gossip import PeerView, gossip_round, rounds_to_convergence
from repro.sim import (WorkloadSpec, make_profile, make_requests, two_phase,
                       uniform_phases)


def _run(ledger: str, seed: int = 0):
    net = Network(mode="decentralized", seed=seed, ledger_mode=ledger,
                  duel=DuelParams(p_d=0.2, k_judges=2), init_balance=100.0)
    for i in range(4):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.5 + 0.1 * i),
                          policy=NodePolicy(offload_util_threshold=0.8)))
    specs = [WorkloadSpec("node1", two_phase(200, 400, 2.0, 20),
                          output_mean=4096, slo_s=300)] + [
        WorkloadSpec(f"node{i}", uniform_phases(400, 20), output_mean=4096,
                     slo_s=300) for i in (2, 3, 4)]
    t0 = time.perf_counter()
    net.run(make_requests(specs, seed=13 + seed), until=400.0)
    return net, time.perf_counter() - t0


def run_p2c(setting: str = "setting2", seed: int = 0):
    """BEYOND-PAPER ablation: power-of-two-choices on top of PoS sampling."""
    from benchmarks.settings import T_END, build_network
    from repro.sim import make_requests as mk
    out = {}
    for p2 in (False, True):
        net, specs = build_network(setting, "decentralized", seed=seed)
        net.power_of_two = p2
        m = net.run(mk(specs, seed=42 + seed), until=T_END)
        out[p2] = (m.slo_attainment(), m.avg_latency())
    return out


def main(rows: List[str]) -> None:
    t0 = time.perf_counter()
    shared, t_shared = _run("shared")
    chain, t_chain = _run("chain")
    us = (time.perf_counter() - t0) * 1e6
    same = all(abs(shared.ledger_balance(n) - chain.ledger_balance(n)) < 1e-6
               for n in shared.nodes)
    blocks = len(next(iter(chain.chains.values())).blocks)
    verified = all(c.verify_chain() for c in chain.chains.values())
    rows.append(
        f"appC_ledger_ablation,{us:.0f},balances_identical={same};"
        f"blocks={blocks};chains_verify={verified};"
        f"overhead_x={t_chain / max(t_shared, 1e-9):.2f}")

    t0 = time.perf_counter()
    parts = []
    ok = True
    for n in (8, 32, 128):
        rng = np.random.default_rng(0)
        views = [PeerView(f"n{i}", f"tcp://n{i}") for i in range(n)]
        for i in range(n):
            gossip_round(views[i], views[(i + 1) % n])
        for v in views:
            v.heartbeat(1.0)
        r = rounds_to_convergence(views, rng, fanout=2)
        parts.append(f"N{n}={r}")
        ok &= r <= 2 * int(np.ceil(np.log2(n))) + 3
    us = (time.perf_counter() - t0) * 1e6
    rows.append(f"appA2_gossip_convergence,{us:.0f},"
                f"rounds={';'.join(parts)};logN_bound={ok}")

    t0 = time.perf_counter()
    ab = run_p2c()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"beyond_p2c_routing,{us:.0f},"
        f"pos_slo={ab[False][0]:.3f};p2c_slo={ab[True][0]:.3f};"
        f"pos_lat={ab[False][1]:.1f};p2c_lat={ab[True][1]:.1f};"
        f"verdict=marginal_accept_policy_already_load_aware")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
