"""Fig 8: user-level policies — stake, acceptance frequency, offload frequency.

(a) executor share tracks stake (1:2:3:4), (b) executor share tracks accept
frequency (0.25/0.5/0.75/1.0), (c) SLO attainment vs offload frequency
(0.25/0.5/0.75/1.0) saturating at moderate rates.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import WorkloadSpec, make_profile, make_requests, uniform_phases

T_END = 900.0


def _requester_net(seed=0):
    net = Network(mode="decentralized", seed=seed, ledger_mode="shared",
                  duel=DuelParams(p_d=0.0), init_balance=1000.0,
                  restake_interval=None)   # keep stakes as configured
    req_pol = NodePolicy(offload_freq=1.0, accept_freq=0.0,
                         offload_queue_threshold=0,
                         offload_util_threshold=0.0, stake=1.0)
    net.add_node(Node("requester", make_profile(quality=0.5), policy=req_pol))
    return net


def run_stake(seed: int = 0) -> Dict[str, int]:
    net = _requester_net(seed)
    for i, stake in enumerate((1.0, 2.0, 3.0, 4.0)):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.6),
                          policy=NodePolicy(stake=stake, offload_freq=0.0,
                                            accept_freq=1.0,
                                            target_utilization=0.95)))
    specs = [WorkloadSpec("requester", uniform_phases(T_END, 1.0),
                          output_mean=1024, slo_s=480.0)]
    m = net.run(make_requests(specs, seed=5 + seed), until=T_END)
    return {n: net.nodes[n].served_total for n in net.nodes if n != "requester"}


def run_accept(seed: int = 0) -> Dict[str, int]:
    net = _requester_net(seed)
    for i, af in enumerate((0.25, 0.5, 0.75, 1.0)):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.6),
                          policy=NodePolicy(stake=10.0, offload_freq=0.0,
                                            accept_freq=af,
                                            target_utilization=0.95)))
    specs = [WorkloadSpec("requester", uniform_phases(T_END, 1.0),
                          output_mean=1024, slo_s=480.0)]
    m = net.run(make_requests(specs, seed=6 + seed), until=T_END)
    return {n: net.nodes[n].served_total for n in net.nodes if n != "requester"}


def run_offload(seed: int = 0) -> Dict[float, float]:
    """SLO attainment when every node uses offload frequency f, under
    sustained pressure on two hot nodes."""
    out = {}
    for f in (0.0, 0.25, 0.5, 0.75, 1.0):
        net = Network(mode="decentralized", seed=seed, ledger_mode="shared",
                      duel=DuelParams(p_d=0.0), init_balance=500.0)
        for i in range(4):
            net.add_node(Node(
                f"node{i+1}", make_profile(quality=0.6),
                policy=NodePolicy(offload_freq=f, accept_freq=0.8,
                                  offload_util_threshold=0.8)))
        specs = [WorkloadSpec("node1", uniform_phases(T_END, 1.6),
                              output_mean=5120, slo_s=300.0),
                 WorkloadSpec("node2", uniform_phases(T_END, 1.6),
                              output_mean=5120, slo_s=300.0)]
        m = net.run(make_requests(specs, seed=8 + seed), until=T_END)
        out[f] = m.slo_attainment()
    return out


def main(rows: List[str]) -> None:
    t0 = time.perf_counter()
    st = run_stake()
    us = (time.perf_counter() - t0) * 1e6
    vals = [st[f"node{i}"] for i in (1, 2, 3, 4)]
    rows.append(f"fig8a_stake,{us:.0f},served={vals};"
                f"monotone={all(vals[i] <= vals[i+1] for i in range(3))}")

    t0 = time.perf_counter()
    ac = run_accept()
    us = (time.perf_counter() - t0) * 1e6
    vals = [ac[f"node{i}"] for i in (1, 2, 3, 4)]
    rows.append(f"fig8b_accept,{us:.0f},served={vals};"
                f"monotone={all(vals[i] <= vals[i+1] for i in range(3))}")

    t0 = time.perf_counter()
    of = run_offload()
    us = (time.perf_counter() - t0) * 1e6
    slo0, slo25, slo50, slo100 = (of[f] for f in (0.0, 0.25, 0.5, 1.0))
    saturates = (slo25 - slo0) > 2 * max(slo100 - slo25, 0.0) - 1e-9
    rows.append(f"fig8c_offload,{us:.0f},"
                f"slo={[round(of[f],3) for f in (0.0,0.25,0.5,0.75,1.0)]};"
                f"improves={slo100 > slo0};saturates={saturates}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
