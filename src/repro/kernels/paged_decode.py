"""Pallas TPU paged flash-decode: block-table attention over a KV page pool.

The paged serving engine (DESIGN.md §6.1-paged) stores KV in a shared pool
of fixed-size pages; each sequence owns a per-row *block table* mapping
logical page index -> physical page.  Decode attention then has no
contiguous cache to stream — the kernel walks a sequence's pages in logical
order and resolves each one through the block table.

The resolution happens in the BlockSpec ``index_map`` via scalar prefetch:
the block table and per-row lengths are prefetched to SMEM before the body
runs, so the pager can issue the HBM->VMEM DMA for physical page
``bt[b, ip]`` while the previous page is still being processed.

Tuned layout (DESIGN.md §Perf-kernels): the pool is transposed to
``(P, Hkv, page, D)`` so one grid step DMAs **all kv heads of a page in a
single block** — the grid is ``(B, padded_pages // pages_per_step)``
instead of the old one-step-per-``(row × kv head × page)`` walk, and the
GQA score is a single batched ``dot_general`` over the kv-head axis.
``pages_per_step`` replicates the k/v operands with offset index maps so
one step covers several consecutive logical pages (multi-page DMA); the
block table is padded to a multiple of it with scratch-page entries, which
``lengths`` masks out.  The choice per ``(page_size, head_dim, hkv)``
comes from ``repro.kernels.tuning``.

The quantized variant streams int8 pages plus bf16 per-token-per-head
scale pages (a parallel pool indexed by the same block table) and
dequantizes in-body via ``models.attention.kv_dequantize`` — the same
helper the slot path uses, so quantized-paged matches quantized-slot
bit-for-bit at the model layer.  The jnp oracles are
``ref.paged_decode_ref`` / ``ref.paged_decode_quant_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallascompat import tpu_compiler_params
from repro.models.attention import NEG_INF, kv_dequantize
from repro.kernels.tuning import tuning_for


def _paged_kernel(bt_ref, len_ref, q_ref, *refs, page: int, pps: int,
                  quant: bool, scale: float, rep: int):
    """refs: k×pps, v×pps[, k_scale×pps, v_scale×pps], o, acc, m, l.

    ``rep`` (query heads per kv head) is unused here but part of the
    shared kernel signature — the verify kernel needs it to recover each
    q-block row's draft index.
    """
    ip = pl.program_id(1)
    np_ = pl.num_programs(1)
    cache_len = len_ref[pl.program_id(0)]
    n_in = pps * (4 if quant else 2)
    k_refs, v_refs = refs[:pps], refs[pps:2 * pps]
    ks_refs = refs[2 * pps:3 * pps] if quant else ()
    vs_refs = refs[3 * pps:4 * pps] if quant else ()
    o_ref, acc_ref, m_ref, l_ref = refs[n_in:]

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (hkv, rep, d)
    for j in range(pps):
        if quant:
            k = kv_dequantize(k_refs[j][0], ks_refs[j][0][..., None],
                              jnp.float32)             # (hkv, page, d)
            v = kv_dequantize(v_refs[j][0], vs_refs[j][0][..., None],
                              jnp.float32)
        else:
            k = k_refs[j][0].astype(jnp.float32)
            v = v_refs[j][0].astype(jnp.float32)
        # batched over the kv-head axis: every kv head of this page in one
        # contraction — (hkv, rep, d) x (hkv, page, d) -> (hkv, rep, page)
        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,)))) * scale
        # logical token positions of logical page ip*pps + j; garbage and
        # pad pages (block-table entries past the row's allocation) mask
        # out entirely here
        k_pos = (ip * pps + j) * page + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page), 2)
        s = jnp.where(k_pos < cache_len, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[..., None]
                        + jax.lax.dot_general(p, v,
                                              (((2,), (1,)), ((0,), (0,)))))
        m_ref[...] = m_new

    @pl.when(ip == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def _kv_index(bb, ip, bt_ref, len_ref, *, pps, j):
    # physical page for (row bb, logical page ip*pps + j), all kv heads
    return (bt_ref[bb, ip * pps + j], 0, 0, 0)


def _scale_index(bb, ip, bt_ref, len_ref, *, pps, j):
    return (bt_ref[bb, ip * pps + j], 0, 0)


def _q_index(bb, ip, bt_ref, len_ref):
    return (bb, 0, 0, 0)


def _paged_attention(q, k_pool, v_pool, block_tables, lengths, k_scale,
                     v_scale, pages_per_step, interpret, kernel_fn,
                     kq: int):
    """Shared wrapper for decode (kq=1) and verify (kq=K) paged attention.

    q: (B, kq, H, D); pools: (P, page, Hkv, D); scales (quantized pools
    only): (P, page, Hkv, 1); block_tables: (B, maxp) int32; lengths:
    (B,) int32.  Returns (B, kq, H, D).
    """
    b, _, h, d = q.shape
    page, hkv = k_pool.shape[1], k_pool.shape[2]
    maxp = block_tables.shape[1]
    assert h % hkv == 0
    rep = h // hkv
    quant = k_scale is not None
    pps = pages_per_step or tuning_for(page, d, hkv).pages_per_step
    pps = max(1, min(int(pps), maxp))

    # (B, kq, H, D) -> (B, Hkv, kq*rep, D): group the rep query heads of
    # each kv head, K draft positions adjacent so a q-block row's draft
    # index is row // rep
    qr = (q.reshape(b, kq, hkv, rep, d).transpose(0, 2, 1, 3, 4)
          .reshape(b, hkv, kq * rep, d))
    # (P, page, Hkv, D) -> (P, Hkv, page, D): one block = one page across
    # ALL kv heads, so the per-page gather is head-fused into a single DMA
    kr = k_pool.transpose(0, 2, 1, 3)
    vr = v_pool.transpose(0, 2, 1, 3)
    # pad the page walk to a multiple of pps; pad entries point at the
    # scratch page 0 and are masked out via lengths
    pad = (-maxp) % pps
    bt = jnp.pad(block_tables.astype(jnp.int32), ((0, 0), (0, pad)))
    lens = lengths.astype(jnp.int32)

    grid = (b, (maxp + pad) // pps)
    kernel = functools.partial(kernel_fn, page=page, pps=pps, quant=quant,
                               scale=d ** -0.5, rep=rep)
    kv_spec = [pl.BlockSpec((1, hkv, page, d),
                            functools.partial(_kv_index, pps=pps, j=j))
               for j in range(pps)]
    in_specs = [pl.BlockSpec((1, hkv, kq * rep, d), _q_index)] \
        + kv_spec + kv_spec
    inputs = [qr] + [kr] * pps + [vr] * pps
    if quant:
        sc_spec = [pl.BlockSpec((1, hkv, page),
                                functools.partial(_scale_index, pps=pps, j=j))
                   for j in range(pps)]
        in_specs += sc_spec + sc_spec
        ksr = k_scale[..., 0].transpose(0, 2, 1)       # (P, Hkv, page)
        vsr = v_scale[..., 0].transpose(0, 2, 1)
        inputs += [ksr] * pps + [vsr] * pps

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, hkv, kq * rep, d), _q_index),
            scratch_shapes=[
                pltpu.VMEM((hkv, kq * rep, d), jnp.float32),
                pltpu.VMEM((hkv, kq * rep), jnp.float32),
                pltpu.VMEM((hkv, kq * rep), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, kq * rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lens, *inputs)
    return (out.reshape(b, hkv, kq, rep, d).transpose(0, 2, 1, 3, 4)
            .reshape(b, kq, h, d))


def flash_paged_decode_tpu(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           k_scale=None, v_scale=None,
                           pages_per_step=None,
                           interpret: bool = True) -> jax.Array:
    """q: (B, 1, H, D); pools: (P, page, Hkv, D); block_tables: (B, maxp)
    int32; lengths: (B,) int32 valid tokens per row.  For int8 pools pass
    ``k_scale``/``v_scale``: (P, page, Hkv, 1) per-token-per-head scales.
    ``pages_per_step`` overrides the recorded tuning.  Returns (B, 1, H, D).
    """
    return _paged_attention(q, k_pool, v_pool, block_tables, lengths,
                            k_scale, v_scale, pages_per_step, interpret,
                            _paged_kernel, kq=1)
