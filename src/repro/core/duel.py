"""Duel-and-judge mechanism (paper §4.2, Figure 3).

A fraction p_d of delegated requests becomes a *duel*: two PoS-sampled
executors both answer; k PoS-sampled judges compare the two responses
pairwise; majority decides.  The loser is slashed P from its stake, the winner
earns R_add, each voting judge earns a judge fee.  The outcome is recorded on
the credit ledger (broadcast as a block in the full-chain path).

Quality model (Assumption 5.3): executor i with latent quality q_i beats j
with probability  P(i > j) = 1/2 (1 + q_i - q_j)  — this is the pairwise form
whose selection-weighted aggregate gives Q_i = 1/2 (1 + q_i - Q̄).  Judges
observe the true winner with accuracy ``judge_accuracy`` (noisy comparisons).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ledger import CreditOp


@dataclass(frozen=True)
class DuelParams:
    p_d: float = 0.1          # duel rate over delegated requests
    k_judges: int = 2         # judges per duel (paper ablation uses k=2)
    r_add: float = 0.5        # winner bonus
    penalty: float = 0.5      # loser stake slash P
    judge_fee: float = 0.1    # per-judge reward for correct-majority service
    judge_accuracy: float = 0.9


@dataclass(frozen=True)
class DuelOutcome:
    duel_id: str
    executor_a: str
    executor_b: str
    judges: Tuple[str, ...]
    votes_a: int
    winner: str
    loser: str
    ops: Tuple[CreditOp, ...]


def true_win_prob(q_a: float, q_b: float) -> float:
    """P(a beats b) = 1/2 (1 + q_a - q_b), clipped to [0, 1]."""
    return float(np.clip(0.5 * (1.0 + q_a - q_b), 0.0, 1.0))


def run_duel(duel_id: str, executor_a: str, executor_b: str,
             judges: Sequence[str], q: Dict[str, float],
             params: DuelParams, rng: np.random.Generator,
             treasury: str = "__treasury__") -> DuelOutcome:
    """Resolve one duel and emit the ledger ops that settle it.

    The winner bonus and judge fees are funded by the treasury (system mint
    account); the loser penalty is a stake slash (burned), exactly matching
    the paper's 'additional reward R_add' / 'penalty P' accounting in §5.
    """
    p_a = true_win_prob(q.get(executor_a, 0.5), q.get(executor_b, 0.5))
    true_winner = executor_a if rng.random() < p_a else executor_b

    votes_a = 0
    for _ in judges:
        correct = rng.random() < params.judge_accuracy
        vote = true_winner if correct else (
            executor_b if true_winner == executor_a else executor_a)
        votes_a += int(vote == executor_a)

    winner = executor_a if votes_a * 2 > len(judges) else (
        executor_b if votes_a * 2 < len(judges) else true_winner)  # tie → truth
    loser = executor_b if winner == executor_a else executor_a

    ops: List[CreditOp] = [
        CreditOp("transfer", treasury, winner, params.r_add, ref=duel_id),
        CreditOp("slash", loser, "", params.penalty, ref=duel_id),
    ]
    ops += [CreditOp("transfer", treasury, j, params.judge_fee, ref=duel_id)
            for j in judges]
    return DuelOutcome(duel_id, executor_a, executor_b, tuple(judges),
                       votes_a, winner, loser, tuple(ops))


def expected_extra_requests(n_requests: int, alpha: float, p_d: float,
                            k: int) -> float:
    """Paper §7.1: expected duel overhead = N · α · p_d · (1 + k)."""
    return n_requests * alpha * p_d * (1 + k)
