"""Sharding rules: map every param / batch / cache leaf to a PartitionSpec.

Scheme (baseline, recorded in the roofline):
* batch dims          -> ("pod", "data")            (data parallel)
* heads / ffn / vocab / experts / recurrence width -> "model" (tensor/expert
  parallel)
* the matching contraction dim of each weight      -> "data"  (FSDP; XLA
  all-gathers weights on use, reduce-scatters grads)
* KV-cache sequence dim at decode                  -> "model" (sequence-
  sharded attention; queries are tiny at decode so this is the only way long
  caches fit HBM)

Any axis that does not divide its mesh extent falls back to None (e.g.
36 heads on a 16-way model axis stay unsharded in shard-strict spots; GSPMD
handles uneven cases where we do shard).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# last-path-component -> role
_UP = {"wq", "wk", "wv", "w_gate", "w_up", "w_up1", "w_up2", "w_y", "w_x",
       "w_a", "w_i", "w_z", "w_f", "router", "lm_head"}
_DOWN = {"wo", "w_down", "w_o"}
_EXPERT_UP = {"we_gate", "we_up"}
_EXPERT_DOWN = {"we_down"}


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def _trim(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes that don't evenly divide (keeps shard_map-compatible specs)."""
    out = []
    for dim, axes in zip(shape, spec):
        out.append(axes if _fits(dim, mesh, axes) else None)
    return P(*out)


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Spec for one parameter leaf; ``path`` is the joined pytree path."""
    name = path.split("/")[-1]
    nd = len(shape)
    bx = _batch_axes(mesh)
    data = "data" if "data" in mesh.axis_names else None
    if nd <= 1:
        return P()
    if name == "embed":
        return _trim(("model", data), shape, mesh)
    if name in ("enc_pos", "dec_pos"):
        return _trim((None, "model"), shape, mesh)
    if name in _EXPERT_UP or name in _EXPERT_DOWN:
        # (L, E, d_in, d_out): experts -> model, contraction -> data (FSDP)
        if name in _EXPERT_UP:
            return _trim((None, "model", data, None), shape, mesh)
        return _trim((None, "model", None, data), shape, mesh)
    if name in _UP:
        if nd == 2:
            return _trim((data, "model"), shape, mesh)
        if nd == 3:
            return _trim((None, data, "model"), shape, mesh)
        if nd == 4:   # stacked block-diagonal (G, H, dh, dh)
            return _trim((None, None, data, "model"), shape, mesh)
    if name in _DOWN:
        if nd == 2:
            return _trim(("model", data), shape, mesh)
        if nd == 3:
            return _trim((None, "model", data), shape, mesh)
        if nd == 4:
            return _trim((None, None, "model", data), shape, mesh)
    if name in ("r_z", "r_i", "r_f", "r_o"):   # sLSTM recurrent (G,H,dh,dh)
        return _trim((None, None, None, "model"), shape, mesh)
    if name == "conv_w":
        return _trim((None,) * (nd - 1) + ("model",), shape, mesh)
    if name == "lam":
        return _trim((None,) * (nd - 1) + ("model",), shape, mesh)
    # norms, biases, small leftovers: replicate
    return P()


def params_shardings(params_tree, mesh: Mesh, *, data_fsdp: bool = True):
    """Pytree of NamedShardings matching ``params_tree`` (arrays or structs).

    ``data_fsdp=False`` drops the 'data' (FSDP) axis from every param spec —
    the inference sharding: weights stay TP-resident, no per-step all-gather
    (§Perf variant ``tponly``).
    """

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        spec = param_spec(pstr, leaf.shape, mesh)
        if not data_fsdp:
            spec = P(*(None if a == "data" else
                       (tuple(x for x in a if x != "data") or None)
                       if isinstance(a, tuple) else a for a in spec))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def batch_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Tokens/labels/embeds: shard the leading batch dim."""
    bx = _batch_axes(mesh)
    spec = (bx,) + (None,) * (len(shape) - 1)
    return _trim(spec, shape, mesh)


def batch_shardings(batch_tree, mesh: Mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(leaf.shape, mesh)),
        batch_tree)


def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Decode caches: batch -> data axes; long axes -> model."""
    name = path.split("/")[-1]
    bx = _batch_axes(mesh)
    nd = len(shape)
    if name in ("k", "v", "cross_k", "cross_v", "k_scale", "v_scale"):
        # (L, B, S, Hkv, dh): sequence-sharded KV over "model"
        return _trim((None, bx, "model", None, None), shape, mesh)
    if name == "length":
        return P()
    if name == "C":       # mLSTM matrix state (G, B, H, dh, dh)
        return _trim((None, bx, None, None, "model"), shape, mesh)
    if name == "conv":    # (G, B, cw-1, W)
        return _trim((None, bx) + (None,) * (nd - 3) + ("model",), shape, mesh)
    if name in ("h", "n", "m", "c"):
        # recurrent vector states (G, B, ...) — shard last dim over model
        spec = (None, bx) + (None,) * (nd - 3) + ("model",)
        return _trim(spec, shape, mesh)
    # default: batch only (dim 1 is batch for stacked (L,B,...) caches)
    return _trim((None, bx) + (None,) * (nd - 2), shape, mesh)


def cache_shardings(cache_tree, mesh: Mesh):
    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        return NamedSharding(mesh, cache_spec(pstr, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def opt_shardings(state_tree, params_shard):
    """Optimizer moments mirror the parameter shardings."""
    return {"params": params_shard,
            "mu": params_shard, "nu": params_shard}
