"""Cross-request prefix caching (DESIGN.md §6.1-prefix).

Five families of tests:

1.  Shared hit rule — ``prefix_hit_pages`` / ``prefix_fingerprint_id``
    properties (pure, no model): only whole pages share and the final
    prompt page is never shared (it must recompute to produce the first
    output token's logits).
2.  Engine bit-parity — cached-prefix generations are bit-identical to
    cold ones through divergent suffixes, mid-chain copy-on-write, LIFO
    preemption round-trips on a tight pool, and int8 KV pages; a deeper
    random sweep runs behind ``-m slow``.
3.  Refcount conservation — ``Engine.debug_page_accounting()`` reconciles
    free ∪ cold ∪ held against refcounts exactly through admit/evict/
    preempt churn; ``page_headroom`` never goes negative; engines without
    the cache keep the exact legacy free-list behavior.
4.  Sim twin agreement — the engine's chain walk and the simulated
    ``TokenBucketExecutor(prefix_cache=True)`` both route through the one
    shared ``prefix_hit_pages`` predicate, and the load/digest plumbing
    (``cache_hit_rate``, ``resident_prefixes``) survives the trip through
    ``make_load_digest`` and the network's affinity tie-break.
5.  Disagg handoff skip — decode-side cached pages are pinned, excluded
    from the transferred bytes on BOTH ends, and the transfer-rate EMA
    learner never mistakes a skipped transfer for a slow link.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sim.executor import (ExecutorLoad, make_load_digest, pages_for,
                                prefix_fingerprint_id, prefix_hit_pages)

_MODEL_CACHE = {}


def _smoke_model():
    if "cp" not in _MODEL_CACHE:
        import jax
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        _MODEL_CACHE["cp"] = (cfg, registry.init(jax.random.PRNGKey(0), cfg))
    return _MODEL_CACHE["cp"]


@pytest.fixture(scope="module")
def setup():
    return _smoke_model()


def _shared_reqs(prefix, specs):
    """GenRequests sharing ``prefix`` with per-spec (rid, seed, suffix_len,
    max_new) unique suffixes."""
    from repro.serving import GenRequest
    out = []
    for rid, seed, sfx, max_new in specs:
        suf = np.random.default_rng(seed).integers(2, 400, size=sfx) \
            .astype(np.int32)
        out.append(GenRequest(rid=rid,
                              tokens=np.concatenate([prefix, suf]),
                              max_new=max_new))
    return out


def _results_by_rid(reqs):
    return {r.rid: np.asarray(r.result) for r in reqs}


def _serve_sequential(eng, reqs):
    """Serve one at a time so later requests see earlier ones' pages."""
    got = {}
    for r in reqs:
        got.update(_results_by_rid(eng.serve([r])))
    return got


# ---------------------------------------------------------------------------
# 1. shared hit rule (pure)
# ---------------------------------------------------------------------------

class TestSharedHitRule:
    def test_final_page_never_shared(self):
        # even a fully-matched prompt recomputes its last page: the warm
        # prefill needs that page's logits for the first output token
        assert prefix_hit_pages(32, 16, 32) == 1
        assert prefix_hit_pages(16, 16, 16) == 0
        assert prefix_hit_pages(33, 16, 33) == 2

    def test_only_whole_pages_share(self):
        assert prefix_hit_pages(100, 16, 15) == 0
        assert prefix_hit_pages(100, 16, 16) == 1
        assert prefix_hit_pages(100, 16, 31) == 1

    @given(prompt=st.integers(1, 4096), matched=st.integers(0, 4096),
           page=st.sampled_from([8, 16, 32, 64]))
    @settings(max_examples=60, deadline=None)
    def test_rule_properties(self, prompt, matched, page):
        hits = prefix_hit_pages(prompt, page, matched)
        assert 0 <= hits <= pages_for(prompt, page) - 1
        assert hits <= matched // page
        # the uncached suffix is never empty
        assert prompt - hits * page >= 1
        # monotone in the match length
        assert hits >= prefix_hit_pages(prompt, page, max(0, matched - page))

    def test_fingerprint_is_stable_and_32bit(self):
        a = prefix_fingerprint_id("sys-1")
        assert a == prefix_fingerprint_id("sys-1")
        assert a != prefix_fingerprint_id("sys-2")
        assert 0 <= a < 2 ** 32


# ---------------------------------------------------------------------------
# 2. engine bit-parity
# ---------------------------------------------------------------------------

class TestEnginePrefixParity:
    def test_cached_matches_cold_divergent_suffixes(self, setup):
        """Sequential requests sharing a multi-page prefix hit the chain
        and stay bit-identical to a cache-less paged engine."""
        from repro.serving import Engine
        cfg, params = setup
        prefix = np.random.default_rng(0).integers(2, 400, size=40) \
            .astype(np.int32)
        specs = [("a", 1, 7, 5), ("b", 2, 13, 4), ("c", 3, 2, 6)]
        cold = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=16, num_pages=64)
        ref = _results_by_rid(cold.serve(_shared_reqs(prefix, specs)))
        warm = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=16, num_pages=64, prefix_cache=True)
        got = _serve_sequential(warm, _shared_reqs(prefix, specs))
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])
        assert warm.prefix_hit_tokens > 0, "cache never hit"
        assert warm.prefix_hit_rate > 0.0
        # all rows drained: every surviving page is cold (evictable), none
        # held, and the pool reconciles exactly
        acct = warm.debug_page_accounting()
        assert acct["held"] == 0 and acct["cold"] > 0

    def test_cow_mid_chain_divergence(self, setup):
        """A prompt matching only the first page of a registered chain
        shares exactly that page and recomputes the rest — never mutating
        the shared page (copy-on-write by construction)."""
        from repro.serving import Engine, GenRequest
        cfg, params = setup
        prefix = np.random.default_rng(1).integers(2, 400, size=48) \
            .astype(np.int32)
        diverged = np.concatenate(
            [prefix[:16], (prefix[16:] + 1) % 400]).astype(np.int32)
        tail = np.array([5, 6, 7], np.int32)

        warm = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                      page_size=16, num_pages=64, prefix_cache=True)
        base = _shared_reqs(prefix, [("base", 9, 4, 4)])[0]
        warm.serve([base])                     # registers the full chain
        before = warm.prefix_hit_tokens
        got = _results_by_rid(warm.serve(
            [GenRequest(rid="cow", tokens=np.concatenate([diverged, tail]),
                        max_new=4)]))

        cold = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                      page_size=16, num_pages=64)
        ref = _results_by_rid(cold.serve(
            [GenRequest(rid="cow", tokens=np.concatenate([diverged, tail]),
                        max_new=4)]))
        np.testing.assert_array_equal(ref["cow"], got["cow"])
        assert warm.prefix_hit_tokens - before == 16   # page 0 only
        # the original chain still replays in full after the COW request
        before = warm.prefix_hit_tokens
        rerun = _shared_reqs(prefix, [("again", 10, 4, 4)])[0]
        warm.serve([rerun])
        assert warm.prefix_hit_tokens - before == 48   # all 3 full pages

    def test_tight_pool_preemption_roundtrip(self, setup):
        """Preempt-and-requeue churn on a pool too small for the offered
        load keeps cached-prefix outputs bit-identical."""
        from repro.serving import Engine
        cfg, params = setup
        prefix = np.random.default_rng(2).integers(2, 400, size=40) \
            .astype(np.int32)
        specs = [("a", 1, 7, 6), ("b", 2, 13, 5), ("c", 3, 2, 4),
                 ("d", 4, 20, 6)]
        cold = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                      page_size=16, num_pages=96)
        ref = _results_by_rid(cold.serve(_shared_reqs(prefix, specs)))
        tight = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                       page_size=16, num_pages=12, prefix_cache=True)
        got = _results_by_rid(tight.serve(_shared_reqs(prefix, specs)))
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])
        acct = tight.debug_page_accounting()
        assert acct["held"] == 0
        assert acct["free"] + acct["cold"] == 12

    def test_kv_quant_pages_share_scales(self, setup):
        """int8 KV pages: the scale pools ride the same physical page
        index, so a shared page shares its scales too — quantized cached
        output matches quantized cold output bit-for-bit."""
        from repro.serving import Engine
        cfg, params = setup
        qcfg = cfg.replace(kv_quant=True)
        prefix = np.random.default_rng(3).integers(2, 400, size=40) \
            .astype(np.int32)
        specs = [("a", 1, 6, 4), ("b", 2, 11, 5)]
        cold = Engine(qcfg, params, max_batch=2, bucket=16, paged=True,
                      page_size=16, num_pages=64)
        ref = _results_by_rid(cold.serve(_shared_reqs(prefix, specs)))
        warm = Engine(qcfg, params, max_batch=2, bucket=16, paged=True,
                      page_size=16, num_pages=64, prefix_cache=True)
        got = _serve_sequential(warm, _shared_reqs(prefix, specs))
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])
        assert warm.prefix_hit_tokens > 0

    def test_prefix_cache_requires_paged(self, setup):
        from repro.serving import Engine
        cfg, params = setup
        with pytest.raises(ValueError):
            Engine(cfg, params, max_batch=2, bucket=16, prefix_cache=True)

    @pytest.mark.slow
    @given(page_size=st.sampled_from([8, 16]), pool=st.integers(8, 24),
           seed=st.integers(0, 10 ** 6), shared_prefix=st.integers(17, 64))
    @settings(max_examples=8, deadline=None)
    def test_random_churn_parity_deep(self, page_size, pool, seed,
                                      shared_prefix):
        """Deeper sweep (``-m slow``): random pool geometries, prefix
        lengths, and workloads — cached-prefix churn (hits, COW, cold
        eviction, preemption) never changes greedy outputs, and the pool
        reconciles after every drain."""
        from repro.serving import Engine
        cfg, params = _smoke_model()
        rng = np.random.default_rng(seed)
        prefix = rng.integers(2, 400, size=shared_prefix).astype(np.int32)
        specs = [(f"r{i}", seed + i, int(rng.integers(1, 16)),
                  int(rng.integers(2, 8))) for i in range(5)]
        cold = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=page_size, num_pages=96)
        ref = _results_by_rid(cold.serve(_shared_reqs(prefix, specs)))
        warm = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=page_size, num_pages=pool,
                      prefix_cache=True)
        got = _serve_sequential(warm, _shared_reqs(prefix, specs))
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])
        acct = warm.debug_page_accounting()
        assert acct["held"] == 0


# ---------------------------------------------------------------------------
# 3. refcount conservation / page accounting
# ---------------------------------------------------------------------------

class TestPageAccounting:
    def test_refcounts_reconcile_through_churn(self, setup):
        """Free ∪ cold ∪ held is an exact partition after every serve wave,
        with refcounts equal to the number of row holders — including waves
        that force cold-LRU eviction and preemption."""
        from repro.serving import Engine
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=16, num_pages=10, prefix_cache=True)
        rng = np.random.default_rng(42)
        for wave in range(4):
            # alternating shared and unique prefixes churn the chain: new
            # registrations must evict older cold pages from the tiny pool
            prefix = rng.integers(2, 400, size=int(rng.integers(20, 40))) \
                .astype(np.int32)
            specs = [(f"w{wave}r{i}", int(rng.integers(0, 10 ** 6)),
                      int(rng.integers(1, 10)), int(rng.integers(2, 5)))
                     for i in range(3)]
            eng.serve(_shared_reqs(prefix, specs))
            acct = eng.debug_page_accounting()   # asserts internally
            assert acct["held"] == 0
            assert acct["free"] + acct["cold"] == \
                eng.load_snapshot()["free_pages"]

    def test_page_headroom_never_negative_while_stepping(self, setup):
        """Cold (cached-but-evictable) pages count as free in the snapshot,
        so ExecutorLoad.page_headroom stays in [0, 1] through stepped
        serving with cache hits and revivals."""
        from repro.serving import Engine, EngineExecutor
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=16, num_pages=12, prefix_cache=True)
        ex = EngineExecutor(eng)
        done = []
        ex.bind(None, lambda r, st_, ft: done.append(r))
        prefix = np.random.default_rng(7).integers(2, 400, size=36) \
            .astype(np.int32)
        pending = _shared_reqs(prefix, [(f"r{i}", i, 5 + i, 4)
                                        for i in range(5)])
        while pending or ex.has_work():
            while pending and ex.admit(pending[0]):
                pending.pop(0)
            ex.step()
            ld = ex.load()
            assert 0.0 <= ld.page_headroom <= 1.0
            assert ld.pages_used >= 0
        assert len(done) == 5
        assert ex.load().cache_hit_rate > 0.0

    def test_non_prefix_engine_keeps_legacy_freelist(self, setup):
        """Without prefix_cache the paged engine never parks pages cold:
        the accounting helper still reconciles, with zero cold pages and
        an unchanged free list after a drain."""
        from repro.serving import Engine
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                     page_size=16, num_pages=8)
        reqs = _shared_reqs(
            np.random.default_rng(1).integers(2, 400, size=20)
            .astype(np.int32), [("a", 1, 4, 3), ("b", 2, 6, 3)])
        eng.serve(reqs)
        acct = eng.debug_page_accounting()
        assert acct == {"free": 8, "cold": 0, "held": 0}
        assert eng.prefix_hit_tokens == 0

    def test_pool_growth_flushes_cache(self, setup):
        """Reallocating the pool for a too-large request invalidates every
        registered page; the chain must flush with it."""
        from repro.serving import Engine, GenRequest
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                     page_size=16, num_pages=4, prefix_cache=True)
        small = _shared_reqs(
            np.random.default_rng(2).integers(2, 400, size=20)
            .astype(np.int32), [("a", 1, 4, 3)])
        eng.serve(small)
        assert eng.debug_page_accounting()["cold"] > 0
        big = GenRequest(rid="big", tokens=np.random.default_rng(3)
                         .integers(2, 400, size=90).astype(np.int32),
                         max_new=3)
        eng.serve([big])                     # forces pool growth
        acct = eng.debug_page_accounting()
        assert acct["held"] == 0
        # growth flushed the old chain: a rerun of the small prompt is cold
        before = eng.prefix_hit_tokens
        eng.serve(_shared_reqs(
            np.random.default_rng(2).integers(2, 400, size=20)
            .astype(np.int32), [("a2", 9, 4, 3)]))
        assert eng.prefix_hit_tokens == before


# ---------------------------------------------------------------------------
# 4. sim twin agreement
# ---------------------------------------------------------------------------

class TestSimTwinAgreement:
    def test_engine_chain_walk_matches_shared_rule(self, setup):
        """The engine's content-hash chain walk and the pure
        ``prefix_hit_pages`` rule agree for every divergence point: a
        prompt sharing exactly ``c`` leading tokens with a registered one
        hits exactly ``prefix_hit_pages(len, page, c)`` pages."""
        from repro.serving import Engine
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                     page_size=16, num_pages=64, prefix_cache=True)
        base = np.random.default_rng(4).integers(2, 400, size=56) \
            .astype(np.int32)
        eng.serve(_shared_reqs(base, [("base", 1, 4, 3)]))
        for common in (0, 5, 15, 16, 17, 32, 48, 56):
            probe = np.concatenate(
                [base[:common], (base[common:] + 1) % 400,
                 np.array([3, 4], np.int32)]).astype(np.int32)
            got = len(eng._prefix_lookup_pages(probe))
            want = prefix_hit_pages(len(probe), 16, common)
            assert got == want, (common, got, want)

    def test_sim_executor_hit_accounting_uses_shared_rule(self):
        """The simulated twin's cached-token count per admitted request is
        exactly ``prefix_hit_pages(prompt, page, prefix_tokens) * page``
        once the prefix is LRU-resident (and 0 on first sight)."""
        from repro.core.node import QueuedRequest
        from repro.sim import TokenBucketExecutor, make_profile
        from repro.sim.events import EventLoop
        from repro.sim.workload import Request
        loop = EventLoop()
        ex = TokenBucketExecutor(make_profile(quality=0.6), page_size=16,
                                 prefix_cache=True)
        done = []
        ex.bind(loop, lambda qr, st_, ft: done.append(qr))

        def req(rid, prompt, ptoks):
            return QueuedRequest(
                Request(rid=rid, origin="n", arrival=0.0,
                        prompt_tokens=prompt, output_tokens=8, slo_s=600.0,
                        prefix_id="sys-1", prefix_tokens=ptoks),
                enqueue_time=0.0, delegated=False, origin_node="n")

        assert ex.admit(req("a", 300, 256))
        assert ex.prefix_hit_tokens == 0           # first sight: cold
        assert ex.admit(req("b", 300, 256))
        want = prefix_hit_pages(300, 16, 256) * 16
        assert ex.prefix_hit_tokens == want
        assert ex.admit(req("c", 260, 256))        # prefix ≈ whole prompt
        want += prefix_hit_pages(260, 16, 256) * 16
        assert ex.prefix_hit_tokens == want
        loop.run(until=10 ** 6)
        assert len(done) == 3
        ld = ex.load()
        assert ld.cache_hit_rate > 0.0
        assert prefix_fingerprint_id("sys-1") in ld.resident_prefixes

    def test_digest_carries_cache_fields(self):
        ld = ExecutorLoad(active_streams=1, queued_streams=0,
                          pending_prefill_tokens=0, pending_decode_tokens=0,
                          kv_used=0, kv_budget=100,
                          cache_hit_rate=0.75,
                          resident_prefixes=(11, 22, 33))
        d = make_load_digest(ld, 3.0)
        assert d.cache_hit_rate == 0.75
        assert d.resident_prefixes == (11, 22, 33)

    def test_affinity_filter_breaks_ties_toward_resident_prefix(self):
        """Among near-tied candidates the draw narrows to digest-resident
        peers; with no warm peer (or affinity off) the set is unchanged."""
        from repro.core import Network, Node, NodePolicy
        from repro.core.duel import DuelParams
        from repro.core.gossip import PeerRecord
        from repro.sim import make_profile
        from repro.sim.workload import Request
        net = Network(mode="decentralized", seed=0, init_balance=100.0,
                      duel=DuelParams(p_d=0.0, k_judges=0))
        for nid in ("n0", "n1", "n2"):
            net.add_node(Node(nid, make_profile(quality=0.6),
                              policy=NodePolicy()))
        origin = net.nodes["n0"]
        fp = prefix_fingerprint_id("sys-9")
        warm_d = make_load_digest(ExecutorLoad(
            active_streams=0, queued_streams=0, pending_prefill_tokens=0,
            pending_decode_tokens=0, kv_used=0, kv_budget=100,
            resident_prefixes=(fp,)), 0.0)
        cold_d = make_load_digest(ExecutorLoad(
            active_streams=0, queued_streams=0, pending_prefill_tokens=0,
            pending_decode_tokens=0, kv_used=0, kv_budget=100), 0.0)
        origin.view.merge([
            PeerRecord("n1", 5, True, "tcp://n1", 0.0, digest=warm_d),
            PeerRecord("n2", 5, True, "tcp://n2", 0.0, digest=cold_d)])
        req = Request(rid="r", origin="n0", arrival=0.0, prompt_tokens=300,
                      output_tokens=8, slo_s=600.0, prefix_id="sys-9",
                      prefix_tokens=256)
        assert net._affinity_filter(origin, req, ["n1", "n2"]) == ["n1"]
        # no prefix on the request → untouched
        plain = Request(rid="p", origin="n0", arrival=0.0, prompt_tokens=300,
                       output_tokens=8, slo_s=600.0)
        assert net._affinity_filter(origin, plain, ["n1", "n2"]) == \
            ["n1", "n2"]
        # nobody warm → full set (pressure keeps deciding)
        other = Request(rid="o", origin="n0", arrival=0.0, prompt_tokens=300,
                        output_tokens=8, slo_s=600.0, prefix_id="sys-404",
                        prefix_tokens=256)
        assert net._affinity_filter(origin, other, ["n1", "n2"]) == \
            ["n1", "n2"]
        net.cache_affinity = False
        assert net._affinity_filter(origin, req, ["n1", "n2"]) == \
            ["n1", "n2"]


# ---------------------------------------------------------------------------
# 5. disagg handoff skip
# ---------------------------------------------------------------------------

class TestDisaggHandoffSkip:
    def test_cached_pages_skip_the_wire(self, setup):
        """With a prefix-cached decode engine, repeated shared-prefix
        traffic moves fewer handoff bytes than a cache-less pair — same
        greedy outputs, pins fully released, decode-side cache populated
        by the handoffs themselves."""
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = setup
        prefix = np.random.default_rng(5).integers(2, 400, size=35) \
            .astype(np.int32)
        specs = [("a", 1, 7, 4), ("b", 2, 13, 4), ("c", 3, 2, 4)]
        ref = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                     page_size=16)
        want = _results_by_rid(ref.serve(_shared_reqs(prefix, specs)))

        def drain(ex, reqs):
            done = []
            ex.bind(None, lambda r, st_, ft: done.append(r))
            pending = list(reqs)
            while pending or ex.has_work():
                while pending and ex.admit(pending[0]):
                    pending.pop(0)
                ex.step()
            return _results_by_rid(done)

        def mk_pair(prefix_cache):
            return DisaggEngineExecutor(
                Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                       page_size=16),
                Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                       page_size=16, prefix_cache=prefix_cache))

        cached, plain = mk_pair(True), mk_pair(False)
        got = {}
        for r in _shared_reqs(prefix, specs):
            got.update(drain(cached, [r]))
        base = {}
        for r in _shared_reqs(prefix, specs):
            base.update(drain(plain, [r]))
        for rid in want:
            np.testing.assert_array_equal(want[rid], got[rid])
            np.testing.assert_array_equal(want[rid], base[rid])
        assert cached.decode.prefix_hit_tokens > 0
        assert cached.prefill.stats.handoff_bytes < \
            plain.prefill.stats.handoff_bytes
        # both ends agree on the (reduced) byte count
        assert cached.decode.stats.handoff_bytes == \
            cached.prefill.stats.handoff_bytes
        # pins released, pool reconciles: a leaked pin would keep its pages
        # held (pin holders count toward the refcount reconciliation)
        assert cached.decode.debug_page_accounting()["held"] == 0

    def test_transfer_ema_ignores_skipped_transfers(self):
        """Satellite regression: a window in which every handoff was
        cache-skipped shows zero byte growth — the per-node transfer-rate
        EMA must treat it as an idle link, not a slow one."""
        from repro.core import Network
        net = Network(mode="single")
        net._observe_transfer_rate("n", 1.0, 10_000)
        net._observe_transfer_rate("n", 2.0, 30_000)   # real transfer
        learned = dict(net._transfer_rate_ema)
        assert learned
        # cached handoffs: cumulative bytes unchanged across sightings
        net._observe_transfer_rate("n", 3.0, 30_000)
        net._observe_transfer_rate("n", 4.0, 30_000)
        assert net._transfer_rate_ema == learned
        # the baseline still advances, so the next real transfer is rated
        # over its own window only
        assert net._transfer_obs["n"][0] == 4.0

    def test_handoff_bytes_exclude_cached_tokens(self, setup):
        """KVHandoff.kv_bytes scales with (length - cached_tokens): the
        skipped pages are charged on neither end."""
        import jax.numpy as jnp
        from repro.serving.engine import KVHandoff
        kw = dict(req=None, out=[1], logits=jnp.zeros((1, 8)), page_size=16)
        h_full = KVHandoff(k=jnp.zeros((2, 4, 16, 1, 4)),
                           v=jnp.zeros((2, 4, 16, 1, 4)), length=64, **kw)
        h_skip = KVHandoff(k=jnp.zeros((2, 2, 16, 1, 4)),
                           v=jnp.zeros((2, 2, 16, 1, 4)), length=64,
                           cached_tokens=32, **kw)
        assert h_skip.kv_bytes == h_full.kv_bytes // 2
