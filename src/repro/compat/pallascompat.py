"""Pallas-TPU API compatibility.

The TPU compiler-params dataclass was renamed across JAX versions:
``pltpu.TPUCompilerParams`` (0.4.x–0.6) became ``pltpu.CompilerParams``
(0.7+).  Kernels route through :func:`tpu_compiler_params` so they lower on
whichever name the installed toolchain provides.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object under its current name."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
