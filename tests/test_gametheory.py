"""§5 game-theory module: Prop 5.6 verification + Thm 5.8 convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.creditsim import CreditSimParams, simulate
from repro.core.gametheory import (GameParams, group_share, integrate,
                                   payoff_delta, share_rhs,
                                   verify_proposition_56)


def _params(q, p_d=0.3):
    q = jnp.asarray(q)
    return GameParams(q=q, c=jnp.full(q.shape, 0.3), p_d=p_d,
                      R_add=1.0, P=1.0)


class TestLemma55:
    def test_payoff_formula(self):
        p = _params([0.8, 0.2])
        s = jnp.array([1.0, 1.0])
        d = payoff_delta(p, s)
        # Q̄ = 0.5; Q_hi = 0.5(1+0.8-0.5)=0.65; Δ = (1-0.3)+0.3(0.65-0.35)
        assert float(d[0]) == pytest.approx(0.7 + 0.3 * (0.65 - 0.35))
        assert float(d[1]) == pytest.approx(0.7 + 0.3 * (0.35 - 0.65))


class TestProp56:
    @given(st.lists(st.floats(0.05, 0.95), min_size=2, max_size=8),
           st.floats(0.5, 5.0))
    @settings(max_examples=25, deadline=None)
    def test_analytic_equals_finite_difference(self, qs, s0):
        p = _params(qs)
        err = verify_proposition_56(p, jnp.full((len(qs),), s0))
        assert err < 1e-2

    def test_shares_sum_invariant(self):
        p = _params([0.9, 0.5, 0.1])
        rhs = share_rhs(p, jnp.array([1.0, 2.0, 3.0]))
        assert float(jnp.sum(rhs)) == pytest.approx(0.0, abs=1e-7)


class TestThm58:
    def test_high_quality_group_share_increases(self):
        p = _params([0.9, 0.8, 0.2, 0.1], p_d=0.5)
        _, shares = integrate(p, jnp.ones(4), dt=0.1, steps=5000)
        hi = p.q > 0.5
        traj = [float(group_share(shares[i], hi))
                for i in range(0, 5000, 250)]
        assert all(np.diff(traj) > -1e-6)
        assert traj[-1] > 0.8

    def test_equal_quality_stays_balanced(self):
        p = _params([0.5, 0.5, 0.5, 0.5])
        _, shares = integrate(p, jnp.ones(4), steps=1000)
        np.testing.assert_allclose(np.asarray(shares[-1]), 0.25, atol=1e-4)

    def test_montecarlo_agrees_with_ode(self):
        q = jnp.array([0.85, 0.75, 0.25, 0.15])
        cp = CreditSimParams(q=q, c=jnp.full((4,), 0.3), p_d=0.5,
                             R_add=1.0, P=1.0)
        traj, wins, duels = simulate(cp, jnp.ones(4) * 10.0,
                                     jax.random.PRNGKey(0), steps=1200)
        sh = np.asarray(traj[-1] / traj[-1].sum())
        assert sh[:2].sum() > 0.75
        wr = np.asarray(wins) / np.maximum(np.asarray(duels), 1)
        assert wr[0] > wr[3]
