"""Family registry: uniform (init / apply / prefill / decode_step) interface."""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import dense, moe, rglru, whisper, xlstm
from repro.models.config import ModelConfig


class Family(NamedTuple):
    init: Callable
    apply: Callable          # full-sequence forward -> logits (or (logits, aux))
    prefill: Callable        # -> (last logits, cache)
    decode_step: Callable    # (params, cfg, cache, token) -> (logits, cache)
    has_aux: bool = False
    slot_decode: bool = False  # per-row cache lengths + prefill last_positions
                               # (slot-based continuous batching, DESIGN.md §6.1)
    # paged-KV capability (DESIGN.md §6.1, paged backend): all three are set
    # together or not at all.  paged_decode decodes against gathered pages
    # with per-row lengths; init_paged_pools allocates the shared page pools;
    # prefill_to_pages scatters a contiguous prefill cache into pages.
    paged_decode: Optional[Callable] = None
    init_paged_pools: Optional[Callable] = None
    prefill_to_pages: Optional[Callable] = None
    # speculative-decoding capability (DESIGN.md §6.1-spec): verify K new
    # tokens (pending + drafts) in one forward against the paged pools,
    # returning logits at every position.  Requires the paged capability.
    paged_verify: Optional[Callable] = None


FAMILIES: Dict[str, Family] = {
    "dense": Family(dense.init, dense.apply, dense.prefill, dense.decode_step,
                    slot_decode=True, paged_decode=dense.paged_decode_step,
                    init_paged_pools=dense.init_paged_pools,
                    prefill_to_pages=dense.prefill_to_pages,
                    paged_verify=dense.paged_verify_step),
    "vlm": Family(dense.init, dense.apply, dense.prefill, dense.decode_step,
                  slot_decode=True, paged_decode=dense.paged_decode_step,
                  init_paged_pools=dense.init_paged_pools,
                  prefill_to_pages=dense.prefill_to_pages,
                  paged_verify=dense.paged_verify_step),
    "moe": Family(moe.init, moe.apply, moe.prefill, moe.decode_step,
                  has_aux=True),
    "hybrid": Family(rglru.init, rglru.apply, rglru.prefill, rglru.decode_step),
    "ssm": Family(xlstm.init, xlstm.apply, xlstm.prefill, xlstm.decode_step),
    "audio": Family(whisper.init, whisper.apply, whisper.prefill,
                    whisper.decode_step),
}


def get_family(cfg: ModelConfig) -> Family:
    return FAMILIES[cfg.family]


def init(key: jax.Array, cfg: ModelConfig):
    return get_family(cfg).init(key, cfg)


def apply_logits(params, cfg: ModelConfig, batch: Dict, **kw) -> jax.Array:
    """Forward pass returning logits only (aux dropped)."""
    fam = get_family(cfg)
    out = fam.apply(params, cfg, batch, **kw)
    return out[0] if fam.has_aux else out


def apply_with_aux(params, cfg: ModelConfig, batch: Dict, **kw
                   ) -> Tuple[jax.Array, jax.Array]:
    fam = get_family(cfg)
    out = fam.apply(params, cfg, batch, **kw)
    if fam.has_aux:
        return out
    return out, jnp.zeros((), jnp.float32)


def params_shape(cfg: ModelConfig):
    """Parameter pytree as ShapeDtypeStructs — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
