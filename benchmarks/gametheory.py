"""§5 (Theorem 5.8): stake-share dynamics converge to high-quality equilibrium.

(i) RK4 integration of the replicator ODE (Prop 5.6) in pure JAX;
(ii) numerical verification of Prop 5.6 (analytic dp/dt == finite diff);
(iii) Monte-Carlo credit simulator agreement (stochastic PoS + duels).
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.creditsim import CreditSimParams, simulate
from repro.core.gametheory import (GameParams, group_share, integrate,
                                   verify_proposition_56)


def main(rows: List[str]) -> None:
    N = 8
    q = jnp.array([0.9, 0.85, 0.8, 0.75, 0.35, 0.3, 0.25, 0.2])
    c = jnp.full((N,), 0.3)
    params = GameParams(q=q, c=c, p_d=0.5, R_add=2.0, P=2.0)
    hi = q > 0.5

    t0 = time.perf_counter()
    _, shares = integrate(params, jnp.ones(N), dt=0.1, steps=20000)
    us = (time.perf_counter() - t0) * 1e6
    ph = np.asarray(group_share(shares, hi))
    ph0, phT = float(ph[0] if ph.ndim else ph), float(
        group_share(shares[-1], hi))
    ph_traj = np.asarray([float(group_share(shares[i], hi))
                          for i in range(0, 20000, 1000)])
    monotone = bool(np.all(np.diff(ph_traj) > -1e-6))
    rows.append(f"thm58_replicator,{us:.0f},p_H_0=0.5;p_H_T={phT:.3f};"
                f"monotone={monotone};converges={phT > 0.8}")

    t0 = time.perf_counter()
    err = verify_proposition_56(params, jnp.ones(N) * 2.0)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(f"prop56_sharedynamics,{us:.0f},fd_vs_analytic_err={err:.2e};"
                f"ok={err < 1e-2}")

    t0 = time.perf_counter()
    cp = CreditSimParams(q=q, c=c, p_d=0.3, R_add=1.0, P=1.0)
    traj, wins, duels = simulate(cp, jnp.ones(N) * 10.0,
                                 jax.random.PRNGKey(0), steps=1500)
    us = (time.perf_counter() - t0) * 1e6
    sh = np.asarray(traj[-1] / traj[-1].sum())
    mc_ph = float(sh[np.asarray(hi)].sum())
    wr = np.asarray(wins / np.maximum(duels, 1))
    wr_ordered = bool(np.mean(wr[:4]) > np.mean(wr[4:]))
    rows.append(f"thm58_montecarlo,{us:.0f},p_H_T={mc_ph:.3f};"
                f"high_q_winrate={np.mean(wr[:4]):.2f};"
                f"low_q_winrate={np.mean(wr[4:]):.2f};ordered={wr_ordered}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
