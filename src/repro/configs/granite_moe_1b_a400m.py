"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] — MoE 32e top-8."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                    # per-expert FFN width
    vocab_size=49155,
    head_dim=64,
    n_experts=32,
    top_k=8,
    rope_theta=1e4,
)
