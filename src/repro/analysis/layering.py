"""layering: the import DAG, the Executor contract, and state boundaries.

Decentralized serving lives or dies by enforceable node-side contracts
(DESIGN.md §7): the Executor layer is the only sanctioned backend
extension point, and the packages below it must stay importable without
dragging the serving stack in.  Four sub-rules:

* ``layering/import-dag`` — each ``repro.*`` subpackage declares the
  subpackages it may import (``ALLOWED_IMPORTS``); any other ``repro``
  import is a violation, and a *new* subpackage must add itself to the
  table (unknown packages are flagged, so layering stays a conscious
  decision).  In particular: ``core`` must not import ``serving`` or
  ``models``; ``sim`` must not import ``serving`` (the sim twins are the
  spec the engines are tested against, so the dependency points at them).
* ``layering/executor-contract`` — every ``Executor`` subclass under
  ``src/`` implements the full contract surface (DESIGN.md §6.1):
  ``admit``, ``load``, ``estimate``, ``n_active`` — defined locally or
  inherited from another repo class (the abstract root itself does not
  count as an implementation).
* ``layering/service-time`` — only the executor layer may call the
  analytic ``BackendProfile.service_time`` (frozen-share scheduling must
  not creep back; DESIGN.md §6.1).
* ``layering/private-state`` — the paged engine's page-pool bookkeeping
  (``_free_pages``, ``_block_tables``, ...) is private to
  ``repro.serving.engine``; everything else reads
  ``Engine.load_snapshot()`` / ``Executor.load()``.
* ``layering/digest-construction`` — gossip ``LoadDigest`` payloads
  (DESIGN.md §6.2-gossip) are constructed only in the executor layer
  (``repro.sim.executor``); everything else — gossip, routing, benches,
  tests — obtains them via ``Executor.digest()`` / ``make_load_digest``,
  so a digest always reflects a real ``ExecutorLoad`` projection rather
  than hand-rolled fields drifting from the load snapshot.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.astutil import imported_modules
from repro.analysis.framework import Checker, Finding, RepoIndex, register

# subpackage -> repro subpackages it may import (itself always allowed).
# Order is the layering: compat/data at the bottom, launch on top.
ALLOWED_IMPORTS: Dict[str, Tuple[str, ...]] = {
    "analysis": (),                       # stdlib-only analyzer
    "compat": (),
    "data": (),
    "obs": (),                            # stdlib-only trace/metrics sink
    "sim": ("compat", "obs"),
    "core": ("compat", "obs", "sim"),
    "models": ("compat",),
    "kernels": ("compat", "models"),      # ref oracles live in models
    "configs": ("compat", "models"),
    "training": ("compat", "models", "data"),
    "serving": ("compat", "obs", "sim", "models", "kernels"),
    "launch": ("compat", "obs", "sim", "core", "models", "kernels",
               "serving", "configs", "training", "data"),
}

# the Executor contract surface (DESIGN.md §6.1); bind() has a concrete
# default on the ABC so it is not part of the required surface
EXECUTOR_ROOT = "Executor"
EXECUTOR_REQUIRED = ("admit", "load", "estimate", "n_active")

# BackendProfile.service_time callers (frozen-share guard)
SERVICE_TIME_ALLOWED = ("src/repro/sim/executor.py",
                        "src/repro/sim/servicemodel.py",
                        "tests/test_executor.py")

# paged-engine page-pool privates and their one sanctioned home
PRIVATE_STATE = frozenset({"_free_pages", "_row_pages", "_block_tables",
                           "_num_pages", "_pools", "_slot_seq",
                           # prefix-cache internals (DESIGN.md §6.1-prefix):
                           # chain/refcount/cold-LRU/pin state is engine-
                           # private; other layers read load_snapshot()'s
                           # cached_pages / prefix_hit_rate /
                           # resident_prefixes or call prefix_pin()
                           "_chain", "_page_hash", "_page_ref", "_cold",
                           "_head_lru", "_pinned"})
PRIVATE_STATE_HOME = "src/repro/serving/engine.py"

# gossip LoadDigest construction and its one sanctioned home (DESIGN.md
# §6.2-gossip); everyone else calls Executor.digest() / make_load_digest
DIGEST_CTOR = "LoadDigest"
DIGEST_HOME = "src/repro/sim/executor.py"


def _subpackage(module: str) -> str:
    """'repro.sim.executor' -> 'sim'; bare 'repro' -> ''. """
    parts = module.split(".")
    return parts[1] if len(parts) > 1 and parts[0] == "repro" else ""


@register
class LayeringChecker(Checker):
    rule_id = "layering"
    description = ("import-DAG contract, Executor contract surface, "
                   "service_time and page-pool state boundaries")

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        yield from self._import_dag(repo)
        yield from self._executor_contract(repo)
        yield from self._restricted_access(repo)

    # ---------------------------------------------------------- import DAG
    def _import_dag(self, repo: RepoIndex) -> Iterable[Finding]:
        for rel in repo.py_files():
            if not rel.startswith("src/repro/"):
                continue          # tests/benchmarks may import any layer
            mod = repo.module_name(rel) or ""
            sub = _subpackage(mod)
            if not sub:
                continue
            tree = repo.tree(rel)
            if tree is None:
                continue
            if sub not in ALLOWED_IMPORTS:
                yield Finding(
                    "layering/import-dag", rel, 1,
                    f"subpackage 'repro.{sub}' has no layering entry; add "
                    f"it to repro.analysis.layering.ALLOWED_IMPORTS to "
                    f"declare its place in the import DAG")
                continue
            allowed = set(ALLOWED_IMPORTS[sub]) | {sub}
            seen: Set[Tuple[str, int]] = set()
            for imported, line in imported_modules(tree):
                tgt = _subpackage(imported)
                if not imported.startswith("repro") or not tgt:
                    continue
                if tgt not in allowed and (tgt, line) not in seen:
                    seen.add((tgt, line))
                    yield Finding(
                        "layering/import-dag", rel, line,
                        f"'repro.{sub}' must not import 'repro.{tgt}' "
                        f"(allowed: "
                        f"{', '.join(sorted(allowed - {sub})) or 'none'})")

    # -------------------------------------------------- Executor contract
    def _executor_contract(self, repo: RepoIndex) -> Iterable[Finding]:
        # class name -> (rel, lineno, base names, method names); names are
        # unique in this codebase, later definitions win deterministically
        index: Dict[str, Tuple[str, int, List[str], Set[str]]] = {}
        for rel in repo.py_files():
            if not rel.startswith("src/"):
                continue          # test fakes may be deliberately partial
            tree = repo.tree(rel)
            if tree is None:
                continue
            for node in ast.walk(tree):
                if not isinstance(node, ast.ClassDef):
                    continue
                bases = []
                for b in node.bases:
                    if isinstance(b, ast.Name):
                        bases.append(b.id)
                    elif isinstance(b, ast.Attribute):
                        bases.append(b.attr)
                methods = {m.name for m in node.body
                           if isinstance(m, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))}
                index[node.name] = (rel, node.lineno, bases, methods)

        def is_executor(name: str, seen: Set[str]) -> bool:
            if name == EXECUTOR_ROOT:
                return True
            if name in seen or name not in index:
                return False
            seen.add(name)
            return any(is_executor(b, seen) for b in index[name][2])

        def inherited(name: str, seen: Set[str]) -> Set[str]:
            """Methods implemented by ``name`` or its repo ancestors,
            excluding the abstract root."""
            if name == EXECUTOR_ROOT or name in seen or name not in index:
                return set()
            seen.add(name)
            out = set(index[name][3])
            for b in index[name][2]:
                out |= inherited(b, seen)
            return out

        for name, (rel, line, bases, _methods) in sorted(index.items()):
            if name == EXECUTOR_ROOT or not is_executor(name, set()):
                continue
            have = inherited(name, set())
            missing = [m for m in EXECUTOR_REQUIRED if m not in have]
            if missing:
                yield Finding(
                    "layering/executor-contract", rel, line,
                    f"Executor subclass '{name}' is missing the contract "
                    f"surface: {', '.join(missing)} (DESIGN.md §6.1)")

    # ------------------------------------------------- restricted access
    def _restricted_access(self, repo: RepoIndex) -> Iterable[Finding]:
        for rel in repo.py_files():
            tree = repo.tree(rel)
            if tree is None:
                continue
            check_service = rel not in SERVICE_TIME_ALLOWED
            check_private = rel != PRIVATE_STATE_HOME
            check_digest = rel != DIGEST_HOME
            if not (check_service or check_private or check_digest):
                continue
            for node in ast.walk(tree):
                if check_service and isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "service_time":
                    yield Finding(
                        "layering/service-time", rel, node.lineno,
                        "direct BackendProfile.service_time call outside "
                        "the executor layer (route through Executor."
                        "admit/load/estimate; DESIGN.md §6.1)")
                elif check_private and isinstance(node, ast.Attribute) \
                        and node.attr in PRIVATE_STATE:
                    yield Finding(
                        "layering/private-state", rel, node.lineno,
                        f"page-pool private '{node.attr}' accessed outside "
                        f"the paged engine (read Engine.load_snapshot() / "
                        f"Executor.load() instead)")
                elif check_digest and isinstance(node, ast.Call) \
                        and ((isinstance(node.func, ast.Name)
                              and node.func.id == DIGEST_CTOR)
                             or (isinstance(node.func, ast.Attribute)
                                 and node.func.attr == DIGEST_CTOR)):
                    yield Finding(
                        "layering/digest-construction", rel, node.lineno,
                        "LoadDigest constructed outside the executor layer "
                        "(build digests via Executor.digest() / "
                        "make_load_digest; DESIGN.md §6.2-gossip)")
