"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape)`` returns the abstract batch for lowering; decode
shapes additionally need ``cache_struct`` (built by abstract evaluation of the
prefill, so every family's cache layout — KV rings, RG-LRU states, mLSTM
matrix memories, Whisper cross-KV — comes out right by construction).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, InputShape, get_config
from repro.models import registry
from repro.models.config import ModelConfig

TOK = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, batch: int, seq: int,
                 with_labels: bool) -> Dict:
    """Abstract model-input batch for one (config, B, S)."""
    dt = jnp.dtype(cfg.dtype)
    out: Dict = {}
    if cfg.family == "audio":
        # frontend STUB: precomputed mel/conv frame embeddings
        out["encoder_embeds"] = _sds((batch, cfg.encoder_seq, cfg.d_model), dt)
        out["tokens"] = _sds((batch, seq), TOK)
    elif cfg.embeds_input:
        # frontend STUB: precomputed vision patch embeddings + (t,h,w) ids
        out["embeds"] = _sds((batch, seq, cfg.d_model), dt)
        out["positions"] = _sds((batch, seq, 3), TOK)
    else:
        out["tokens"] = _sds((batch, seq), TOK)
    if with_labels:
        out["labels"] = _sds((batch, seq), TOK)
    return out


def params_struct(cfg: ModelConfig):
    return registry.params_shape(cfg)


def state_struct(cfg: ModelConfig):
    from repro.training.train_step import state_shape
    return state_shape(cfg)


def cache_struct(cfg: ModelConfig, batch: int, seq: int):
    """Abstract decode cache for a fully-prefilled context of length ``seq``."""
    fam = registry.get_family(cfg)
    ps = params_struct(cfg)
    bs = batch_struct(cfg, batch, seq, with_labels=False)

    def run(params, b):
        _, cache = fam.prefill(params, cfg, b, q_chunk=1024, kv_chunk=1024,
                               capacity=seq)
        return cache

    return jax.eval_shape(run, ps, bs)


def token_struct(batch: int):
    return _sds((batch, 1), TOK)


def reduced_depth(cfg: ModelConfig, k_groups: int) -> ModelConfig:
    """Same config with k pattern-groups of layers (roofline extrapolation)."""
    if cfg.family == "hybrid":
        pat = len(cfg.block_pattern or ("rec", "rec", "attn"))
        tail = cfg.n_layers % pat
        return cfg.replace(n_layers=pat * k_groups + tail)
    if cfg.family == "ssm":
        pat = len(cfg.xlstm_pattern or ("m", "s"))
        tail = cfg.n_layers % pat
        return cfg.replace(n_layers=pat * k_groups + tail)
    if cfg.family == "audio":
        return cfg.replace(n_layers=k_groups, n_encoder_layers=k_groups)
    return cfg.replace(n_layers=k_groups)


def n_groups_of(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.block_pattern or ("rec", "rec", "attn"))
    if cfg.family == "ssm":
        return cfg.n_layers // len(cfg.xlstm_pattern or ("m", "s"))
    return cfg.n_layers                     # audio: Le == Ld == n_layers


def input_specs(arch: str, shape_name: str,
                cfg_override: Optional[ModelConfig] = None) -> Dict:
    """Everything dryrun/train/serve need for one (arch × input shape)."""
    shp = INPUT_SHAPES[shape_name]
    cfg = cfg_override or get_config(arch, shape_name)
    out = {"cfg": cfg, "shape": shp}
    if shp.kind == "train":
        out["state"] = state_struct(cfg)
        out["batch"] = batch_struct(cfg, shp.global_batch, shp.seq_len,
                                    with_labels=True)
    elif shp.kind == "prefill":
        out["params"] = params_struct(cfg)
        out["batch"] = batch_struct(cfg, shp.global_batch, shp.seq_len,
                                    with_labels=False)
    else:  # decode
        out["params"] = params_struct(cfg)
        out["cache"] = cache_struct(cfg, shp.global_batch, shp.seq_len)
        out["token"] = token_struct(shp.global_batch)
    return out
