"""Game-theoretic stake dynamics (paper §5) in pure JAX.

Implements the continuous-time system of Assumptions 5.1-5.4:

    p_i      = s_i / Σ_j s_j                        (PoS selection prob.)
    Q̄        = Σ_i p_i q_i                          (selection-weighted quality)
    Q_i      = ½ (1 + q_i − Q̄)                      (duel win probability)
    Δ_i      = (R − c_i) + p_d [Q_i R_add − (1−Q_i) P]   (Lemma 5.5)
    π_i      = λ p_i Δ_i
    ds_i/dt  = η π_i                                (Assumption 5.4)

and the induced share dynamics (Prop 5.6):

    dp_i/dt = ηλ/S · p_i (Δ_i − Δ̄),   Δ̄ = Σ_j p_j Δ_j .

Integration is RK4 under ``jax.lax.scan`` so the whole trajectory is one jit'd
program.  ``verify_proposition_56`` checks the analytic share derivative
against the finite difference of the stake integration — a direct numerical
validation of the paper's Proposition 5.6.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GameParams(NamedTuple):
    q: jax.Array        # (N,) intrinsic quality q_i ∈ [0,1]
    c: jax.Array        # (N,) per-request cost c_i > 0
    lam: float = 10.0   # delegated request arrival rate λ
    R: float = 1.0      # base reward
    p_d: float = 0.1    # duel rate
    R_add: float = 0.5  # duel winner bonus
    P: float = 0.5      # duel loser penalty
    eta: float = 0.05   # stake growth constant η


def payoff_delta(params: GameParams, s: jax.Array) -> jax.Array:
    """Δ_i(t) of Lemma 5.5 given current stakes s (N,)."""
    p = s / jnp.sum(s)
    q_bar = jnp.sum(p * params.q)
    q_i = 0.5 * (1.0 + params.q - q_bar)
    return (params.R - params.c) + params.p_d * (
        q_i * params.R_add - (1.0 - q_i) * params.P)


def stake_rhs(params: GameParams, s: jax.Array) -> jax.Array:
    """ds/dt = η λ p_i Δ_i (Assumption 5.4 + Lemma 5.5)."""
    p = s / jnp.sum(s)
    return params.eta * params.lam * p * payoff_delta(params, s)


def share_rhs(params: GameParams, s: jax.Array) -> jax.Array:
    """Analytic dp_i/dt of Proposition 5.6 (for verification)."""
    S = jnp.sum(s)
    p = s / S
    delta = payoff_delta(params, s)
    delta_bar = jnp.sum(p * delta)
    return params.eta * params.lam / S * p * (delta - delta_bar)


@functools.partial(jax.jit, static_argnames=("steps",))
def integrate(params: GameParams, s0: jax.Array, dt: float = 0.05,
              steps: int = 2000):
    """RK4 integration; returns (stake trajectory, share trajectory)."""

    def rk4(s, _):
        k1 = stake_rhs(params, s)
        k2 = stake_rhs(params, s + 0.5 * dt * k1)
        k3 = stake_rhs(params, s + 0.5 * dt * k2)
        k4 = stake_rhs(params, s + dt * (k3))
        s_next = s + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
        s_next = jnp.maximum(s_next, 1e-9)   # stakes are nonnegative
        return s_next, s_next

    _, traj = jax.lax.scan(rk4, s0, None, length=steps)
    shares = traj / jnp.sum(traj, axis=-1, keepdims=True)
    return traj, shares


def group_share(shares: jax.Array, mask: jax.Array) -> jax.Array:
    """p_H(t) for a subset H (Proposition 5.7)."""
    return jnp.sum(jnp.where(mask, shares, 0.0), axis=-1)


def verify_proposition_56(params: GameParams, s: jax.Array,
                          dt: float = 1e-4) -> float:
    """Max abs error between analytic dp/dt and finite-difference dp/dt."""
    p0 = s / jnp.sum(s)
    s1 = s + dt * stake_rhs(params, s)
    p1 = s1 / jnp.sum(s1)
    fd = (p1 - p0) / dt
    an = share_rhs(params, s)
    return float(jnp.max(jnp.abs(fd - an)))
