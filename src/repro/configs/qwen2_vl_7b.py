"""Qwen2-VL-7B [arXiv:2409.12191] — VLM backbone, M-RoPE, dynamic resolution.

Vision encoder is a STUB: input_specs feeds precomputed patch embeddings and
(t, h, w) position triples; the language decoder with M-RoPE is implemented.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    embeds_input=True,
    rope_theta=1e6,
)
