"""A small batched serving engine — the node's Model Manager backend.

Real (not simulated) JAX inference with **slot-based continuous batching**
(DESIGN.md §6.1): the engine keeps a persistent decode cache with
``max_batch`` row slots, each resident sequence decoding at its own depth
(per-row cache lengths).  After every decode step finished sequences are
evicted and queued requests are prefilled into the freed slots — a short
request no longer holds the batch hostage for the longest request's budget.
Prompts are right-padded, which causal attention keeps inert, so a request's
greedy output is independent of what it happens to be batched with (wave
batching, ``continuous=False``, produces bit-identical greedy results in
more decode steps).

``Engine(paged=True)`` swaps the per-slot contiguous cache for a **paged KV
cache** (DESIGN.md §6.1, paged backend): a fixed pool of page-sized KV
blocks with a per-sequence block table, grown one page at a time during
decode.  Admission charges a request's *prompt* pages only (not
``prompt + max_new`` as the contiguous slot cache must reserve), finished
sequences return their pages to the pool, and when the pool exhausts
mid-decode the most recently admitted sequence is preempted — its pages
reclaimed, its request requeued at the head of the queue for a greedy-
deterministic restart.  Greedy outputs stay bit-identical to the slot and
wave paths while strictly more requests are resident on the same KV budget.

``Engine(paged=True, prefix_cache=True)`` turns the page pool into a
**cross-request prefix cache** (DESIGN.md §6.1-prefix): every full prompt
page is content-addressed by a page-aligned hash chain, pages carry holder
refcounts, and prefill skips any prefix whose chain is already resident —
the uncached suffix is computed in one multi-token verify forward against
the shared pages.  Divergence mid-page is a chain miss (copy-on-write at
page granularity: the diverging request gets fresh pages from its first
differing page).  Released cached pages go *cold* instead of free — still
content-addressable, evicted LRU-first only when the free list is empty —
so eviction happens strictly at refcount zero.  Greedy outputs stay
bit-identical to a cold prefill: cached pages hold exactly the KV the
cold forward would recompute, and the suffix forward attends to them
through the same block-table indirection.

``Engine(spec_draft=(draft_cfg, draft_params), spec_k=k)`` layers
**speculative decoding** (DESIGN.md §6.1-spec) on top of the paged backend:
a small same-tokenizer draft model proposes ``k`` tokens greedily, the
target verifies all of them in ONE batched multi-token forward
(``Family.paged_verify``), and the longest prefix of drafts matching the
target's own greedy choices is accepted — plus the target's correction
token, carried as next-step logits.  KV pages are claimed for accepted
tokens only (rejected drafts' writes sit beyond the valid length and are
overwritten).  Greedy outputs stay bit-identical to the non-speculative
paged engine: every emitted token is the argmax of the target's logits
over the same prefix, speculation only changes how many target forwards
that takes.

This is the backend used by the runnable examples and the end-to-end
decentralized serving driver (``repro.launch.serve``, via
``repro.serving.executor.EngineExecutor``); the large-scale scheduling
benchmarks use the simulated executor instead (see DESIGN.md §6.1).
"""

from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.obs import WALL, get_registry, get_tracer, wall_now
from repro.models.config import ModelConfig
from repro.serving.sampling import sample
from repro.sim.executor import (paged_admit_ok, pages_for, prefix_hit_pages,
                                quantized_pages)
from repro.sim.servicemodel import (PREFIX_FINGERPRINT_K,
                                    PREFIX_HIT_EMA_BETA, SPEC_ALPHA0,
                                    SPEC_EMA_BETA, SPEC_K)


def _greedy_tokens(logits: "jax.Array", vocab_size: int) -> "jax.Array":
    """Greedy token at every position of ``logits`` (..., V), with padded
    vocab entries masked — the same masking + argmax as the temperature-0
    path of :func:`repro.serving.sampling.sample`, so speculative
    verification reproduces non-speculative greedy choices exactly."""
    lg = logits.astype(jnp.float32)
    if vocab_size < lg.shape[-1]:
        pad_mask = jnp.arange(lg.shape[-1]) >= vocab_size
        lg = jnp.where(pad_mask, -1e30, lg)
    return jnp.argmax(lg, axis=-1).astype(jnp.int32)


@dataclass
class GenRequest:
    rid: str
    tokens: np.ndarray            # (S,) prompt token ids
    max_new: int = 32
    temperature: float = 0.0
    result: Optional[np.ndarray] = None
    # engine metrics (wall-clock)
    enqueued_at: float = 0.0
    started_at: float = 0.0       # admitted into a slot (prefill)
    first_token_at: float = 0.0   # first output token sampled
    finished_at: float = 0.0


@dataclass
class EngineStats:
    served: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    batches: int = 0              # prefill batches
    decode_steps: int = 0         # batched decode_step invocations
    prefill_wall_s: float = 0.0   # wall time inside prefill calls
    decode_wall_s: float = 0.0    # wall time inside decode_step calls
    peak_resident: int = 0        # max concurrently resident sequences
    preempted: int = 0            # paged: preempt-and-requeue events
    handoffs: int = 0             # disagg: KV handoffs extracted/accepted
    handoff_bytes: int = 0        # disagg: valid KV bytes handed off
    # speculative decoding (DESIGN.md §6.1-spec).  decode_tokens counts
    # EMITTED tokens and decode_wall_s the target-side verify walls, so
    # decode_tokens / decode_wall_s is the effective target decode
    # throughput; the draft's own cost is tracked in draft_wall_s.
    spec_steps: int = 0           # verify forwards (each checks spec_k drafts)
    spec_drafted: int = 0         # draft tokens proposed
    spec_accepted: int = 0        # draft tokens matching the target's greedy
    draft_wall_s: float = 0.0     # wall time inside draft prefill/decode jits
    verify_wall_s: float = 0.0    # wall time inside the verify jit


@dataclass
class KVHandoff:
    """A prefilled request leaving a disaggregated prefill engine
    (DESIGN.md §6.1-disagg): its populated KV pages, the tokens it has
    already sampled (the prefill side emits the first token), and the
    next-token logits the decode side resumes from.  ``k``/``v`` are
    page-granular copies — the prefill engine's physical pages are released
    the moment the handoff is extracted; the decode engine scatters them
    into its own pool under fresh page numbers (``Engine.accept_handoff``).
    """

    req: GenRequest
    out: List[int]                # tokens sampled on the prefill side (>= 1)
    length: int                   # valid KV tokens: prompt + len(out)
    k: "jax.Array"                # (L, n_pages, page, Hkv, dh)
    v: "jax.Array"
    logits: "jax.Array"           # (1, V) next-token logits
    page_size: int
    # prefix tokens the DECODE side already holds cached and pinned
    # (DESIGN.md §6.1-prefix): those pages are not gathered into k/v and
    # their bytes never cross the wire.  Always a page multiple.
    cached_tokens: int = 0

    @property
    def kv_bytes(self) -> int:
        """Bytes of *valid* KV crossing the wire — the sim's transfer cost
        model charges the same quantity (prompt-dominated: len(out) is 1
        unless the prefill side raced ahead).  Pages the decode side holds
        cached (``cached_tokens``) never travel, so neither end counts
        them."""
        n_layers, _, _, n_kv, dh = self.k.shape
        return (2 * n_layers * (self.length - self.cached_tokens)
                * n_kv * dh * self.k.dtype.itemsize)


class _Slot:
    """One resident sequence: its request, sampled tokens, cache depth."""

    __slots__ = ("req", "out")

    def __init__(self, req: GenRequest) -> None:
        self.req = req
        self.out: List[int] = []


class Engine:
    """Persistent-slot continuous batching with a jitted step per bucket."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 bucket: int = 64, seed: int = 0,
                 capacity: Optional[int] = None,
                 continuous: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 spec_draft: Optional[Tuple[ModelConfig, Dict]] = None,
                 spec_k: int = SPEC_K) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.continuous = continuous
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        # trace span identity (DESIGN.md §Observability): the owning
        # executor forwards the node id the Node binds onto it
        self.owner = ""
        fam = registry.get_family(cfg)
        # right-padding is only inert with a full cache: a sliding-window
        # ring keeps the last `window` positions of the PADDED sequence, so
        # trailing pads would evict real in-window KV — window configs stay
        # on the left-padded lock-step wave path
        self.slot_decode = fam.slot_decode and cfg.sliding_window is None
        if self.slot_decode:
            self._prefill = jax.jit(
                lambda p, b, cap, lp: fam.prefill(p, cfg, b, q_chunk=256,
                                                  kv_chunk=256, capacity=cap,
                                                  last_positions=lp),
                static_argnums=(2,))
        else:
            # families without per-row cache depths fall back to left-padded
            # lock-step wave batching
            self._prefill = jax.jit(
                lambda p, b, cap: fam.prefill(p, cfg, b, q_chunk=256,
                                              kv_chunk=256, capacity=cap),
                static_argnums=(2,))
        self._decode = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))
        self.eos_id = cfg.eos_id

        # persistent slot state
        self._queue: List[GenRequest] = []
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._lengths = np.zeros(max_batch, np.int64)   # per-row cache depth
        self._cache: Optional[Dict] = None
        self._logits: Optional[jax.Array] = None
        self._capacity = int(capacity or 0)

        # paged-KV state (DESIGN.md §6.1, paged backend)
        self.paged = bool(paged)
        self.page_size = int(page_size)
        if self.paged:
            if not (self.slot_decode and fam.paged_decode is not None):
                raise ValueError(
                    "paged KV requires a paged-capable slot-decode family "
                    "(dense/vlm with full attention)")
            # the decode/verify caches are DONATED: with the pools carried
            # through the layer scan (dense.paged_decode_step), donation
            # makes the page scatter a true in-place update, so step cost
            # is independent of pool size (§Perf-kernels).  Never reuse a
            # cache array after passing it in — the engine always reads the
            # returned cache.
            self._decode_paged = jax.jit(
                lambda p, c, t: fam.paged_decode(p, cfg, c, t),
                donate_argnums=(1,))
            self._scatter_pages = jax.jit(fam.prefill_to_pages,
                                          donate_argnums=(0,))
            self._init_pools = fam.init_paged_pools
            usable = (int(num_pages) if num_pages is not None
                      else max_batch * pages_for(2 * bucket, self.page_size))
            # int8 KV pages: the same HBM budget holds 2x the pages — the
            # shared sim/engine capacity rule (DESIGN.md §6.1-paged)
            usable = quantized_pages(usable, cfg.kv_quant)
            self._num_pages = usable + 1          # page 0 is scratch
            self._pools: Optional[Dict] = None    # lazy device alloc
            self._pool_names = (("k_pool", "v_pool", "k_scale_pool",
                                 "v_scale_pool") if cfg.kv_quant
                                else ("k_pool", "v_pool"))
            self._free_pages: List[int] = list(range(1, self._num_pages))
            self._row_pages: List[List[int]] = [[] for _ in range(max_batch)]
            self._maxp = max(1, pages_for(2 * bucket, self.page_size))
            self._block_tables = np.zeros((max_batch, self._maxp), np.int32)
            # device-resident block table + lengths (§Perf-kernels): the
            # decode cache passes both through, so steady-state decode skips
            # the per-step host->device upload; any host-side mutation
            # (admission, release, page claim) marks them dirty
            self._bt_dev: Optional[jax.Array] = None
            self._len_dev: Optional[jax.Array] = None
            self._tables_dirty = True
            # admission order, for LIFO preemption under pool pressure
            self._slot_seq = np.zeros(max_batch, np.int64)
            self._admit_seq = 0
            # cross-request prefix caching (DESIGN.md §6.1-prefix): pages
            # content-addressed by a page-aligned hash chain over the
            # prompt.  The maps exist (empty) for every paged engine so the
            # pool accounting below is uniform; lookups and registration
            # only happen with ``prefix_cache=True``.
            self._chain: Dict[int, int] = {}      # chain hash -> phys page
            self._page_hash: Dict[int, int] = {}  # phys page -> chain hash
            self._page_ref: Dict[int, int] = {}   # phys page -> holder count
            # cold cached pages: refcount 0 but content still addressable;
            # ordered oldest-touched first, evicted only when the free list
            # is empty (insertion at the MRU end in _drop_page)
            self._cold: "OrderedDict[int, None]" = OrderedDict()
            # depth-1 chain hashes by recency — the resident-prefix
            # fingerprint that load snapshots/digests advertise
            self._head_lru: "OrderedDict[int, None]" = OrderedDict()
            # rid -> pages claimed for an in-flight disagg handoff
            self._pinned: Dict[str, List[int]] = {}
            self.prefix_hit_rate = 0.0
            self.prefix_hit_tokens = 0
            self.prefix_lookup_tokens = 0

        # speculative decoding (DESIGN.md §6.1-spec)
        self.spec = spec_draft is not None
        self.spec_k = int(spec_k) if self.spec else 0
        if self.spec:
            if not self.paged:
                raise ValueError("speculative decoding requires paged=True "
                                 "(the verify step targets the page pools)")
            if fam.paged_verify is None:
                raise ValueError("family has no paged_verify capability")
            if self.spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            draft_cfg, draft_params = spec_draft
            dfam = registry.get_family(draft_cfg)
            if not (dfam.slot_decode and draft_cfg.sliding_window is None):
                raise ValueError("draft model must support slot decode "
                                 "with full attention")
            if (draft_cfg.vocab_size != cfg.vocab_size
                    or draft_cfg.eos_id != cfg.eos_id):
                raise ValueError("draft and target must share the tokenizer "
                                 "(vocab_size / eos_id)")
            self.spec_draft_cfg = draft_cfg
            self.spec_draft_params = draft_params
            self._verify = jax.jit(
                lambda p, c, t: fam.paged_verify(p, cfg, c, t),
                donate_argnums=(1,))
            self._draft_prefill = jax.jit(
                lambda p, b, cap, lp: dfam.prefill(p, draft_cfg, b,
                                                   q_chunk=256, kv_chunk=256,
                                                   capacity=cap,
                                                   last_positions=lp),
                static_argnums=(2,))
            self._draft_decode = jax.jit(
                lambda p, c, t: dfam.decode_step(p, draft_cfg, c, t))
            # draft slot cache: contiguous per-row-depth KV, mirrored to the
            # target's slots (re-prefilled from scratch after preemption)
            self._draft_cache: Optional[Dict] = None
            self._draft_lengths = np.zeros(max_batch, np.int64)
            self._draft_capacity = 0
            # online per-token acceptance-rate EMA, seeded from the same sim
            # constant the SpecTokenBucketExecutor defaults to, so sim and
            # engine agree until real observations move it
            self.spec_alpha = SPEC_ALPHA0
            # accepted-length distribution: spec_accept_hist[a] counts
            # verify steps that accepted exactly a of spec_k drafts
            self.spec_accept_hist = [0] * (self.spec_k + 1)

        # cross-request prefix caching (DESIGN.md §6.1-prefix)
        self.prefix_cache = bool(prefix_cache)
        if self.prefix_cache:
            if not self.paged:
                raise ValueError("prefix caching requires paged=True "
                                 "(it shares pool pages across requests)")
            if fam.paged_verify is None:
                raise ValueError(
                    "prefix caching needs a paged_verify-capable family: "
                    "cached-suffix prefill is a multi-token verify forward")
            if not self.spec:
                # warm prefill reuses the speculative verify kernel: only
                # the uncached suffix is computed, attending to the shared
                # prefix pages through the block-table indirection (the
                # spec engine already built this jit above)
                self._verify = jax.jit(
                    lambda p, c, t: fam.paged_verify(p, cfg, c, t),
                    donate_argnums=(1,))

    def _pad_bucket(self, n: int) -> int:
        b = self.bucket
        return max(b, (n + b - 1) // b * b)

    def _required(self, r: GenRequest) -> int:
        """Worst-case cache tokens a request may touch.  A speculative
        verify writes up to ``spec_k`` positions past the pending token, so
        the spec engine's worst case extends past pad(prompt)+pad(max_new)
        by the draft depth (rejected drafts' writes still need a mapped
        page, even though they never become valid tokens)."""
        extra = self.spec_k if self.spec else 0
        return (self._pad_bucket(len(r.tokens))
                + self._pad_bucket(r.max_new) + extra)

    def _draft_required(self, r: GenRequest) -> int:
        """Draft-cache capacity for ``r``: the page-rounded prefill width
        (the draft prefills the same right-padded prompt batch as the
        target) plus room to decode the pending token and ``spec_k``
        drafts at positions up to ``prompt + max_new - 2 + spec_k``."""
        plen = (-(-self._pad_bucket(len(r.tokens)) // self.page_size)
                * self.page_size)
        return plen + self._pad_bucket(r.max_new + self.spec_k)

    # ------------------------------------------------------------- interface
    def submit(self, r: GenRequest) -> None:
        if self.spec and r.temperature > 0.0:
            raise ValueError(
                "the speculative engine is greedy-only: draft acceptance "
                "compares argmax choices (temperature sampling would need "
                "rejection sampling, which breaks the bit-parity invariant)")
        r.enqueued_at = wall_now()
        self._queue.append(r)

    def requeue(self, r: GenRequest) -> None:
        """Put a preempted/rerouted request back at the head of the queue
        WITHOUT re-stamping ``enqueued_at`` — its queue wait keeps counting
        from the original submission, so ``queue_wait`` stays monotone
        across preemption round-trips (the disagg executor routes
        decode-side preemptions back through the prefill engine)."""
        self._queue.insert(0, r)

    def take_queued(self) -> List[GenRequest]:
        """Drain and return the queue (admission re-routing: the disagg
        executor uses this to pull decode-side preemptions back out, since
        handoffs never travel through the decode engine's own queue)."""
        q, self._queue = self._queue, []
        return q

    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def active_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def queued(self) -> int:
        return len(self._queue)

    def load_snapshot(self) -> Dict[str, object]:
        """Occupancy counts for Executor.load() — the supported view of the
        slot/queue/page-pool bookkeeping (token counts are *remaining* work;
        this dict, not the private pool state, is the sanctioned external
        view — a grep-guard in tests/test_compat.py enforces it)."""
        active = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        snap = dict(
            active_streams=len(active),
            queued_streams=len(self._queue),
            queued_prompt_tokens=sum(len(r.tokens) for r in self._queue),
            queued_new_tokens=sum(r.max_new for r in self._queue),
            pending_decode_tokens=sum(s.req.max_new - len(s.out)
                                      for _, s in active),
            pages_used=0, pages_total=0, free_pages=0, page_size=0,
            cached_pages=0, prefix_hit_rate=0.0, resident_prefixes=())
        if self.paged:
            usable = self._num_pages - 1
            cold = len(self._cold)
            used = usable - len(self._free_pages) - cold
            snap.update(
                pages_used=used, pages_total=usable,
                # cold cached pages are evicted on demand, so admission
                # counts them as free (DESIGN.md §6.1-prefix)
                free_pages=len(self._free_pages) + cold,
                page_size=self.page_size,
                # paged KV charges pages actually held, not reservations
                kv_used=used * self.page_size,
                kv_budget=usable * self.page_size,
                cached_pages=cold,
                prefix_hit_rate=self.prefix_hit_rate,
                resident_prefixes=tuple(reversed(self._head_lru))
                [:PREFIX_FINGERPRINT_K])
        else:
            snap.update(
                kv_used=int(sum(self._lengths[i] + s.req.max_new - len(s.out)
                                for i, s in active)),
                kv_budget=self.max_batch * max(self._capacity, 1))
        return snap

    def serve(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Submit ``reqs`` and pump steps until the engine drains."""
        if not self.slot_decode:
            return self._serve_wave_legacy(reqs)
        for r in reqs:
            self.submit(r)
        while self.has_work():
            self.step()
        return reqs

    def generate_batch(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Serve up to max_batch requests together; returns them completed."""
        assert len(reqs) <= self.max_batch
        return self.serve(reqs)

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        if self.paged:
            self._admit_paged()
            return
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        if resident and any(self._required(r) > self._capacity
                            for r in self._queue):
            # a queued request needs a bigger cache, which can only be
            # allocated while nothing is resident: stop backfilling so the
            # batch drains and the growth branch below runs (otherwise a
            # steady stream of small requests starves the big one forever)
            return
        if not resident:
            # grow the cache while nothing is resident (allocation is static
            # under jit, so capacity only changes between generations)
            needed = max(self._required(r)
                         for r in self._queue[:self.max_batch])
            if self._cache is None or needed > self._capacity:
                self._capacity = max(self._capacity, needed)
                self._cache = None
                self._logits = None
        free = [i for i, s in enumerate(self._slots) if s is None]
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        for r in self._queue:
            # skip requests the current cache can't hold; they are admitted
            # at the next idle point, when capacity can grow
            if free and self._required(r) <= self._capacity:
                take.append((free.pop(0), r))
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._prefill_into(take)

    def _prefill_into(self, take: List[Tuple[int, GenRequest]]) -> None:
        n = len(take)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        for j, (_, r) in enumerate(take):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
        with get_tracer().wall("engine.prefill", who=self.owner,
                               rows=n, tokens=plen * n) as sp:
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)},
                                          self._capacity, jnp.asarray(last))
            logits.block_until_ready()
        self.stats.prefill_wall_s += sp.dt
        self.stats.prefill_tokens += plen * n
        self.stats.batches += 1
        kv = {k: v for k, v in cache.items() if k != "length"}
        rows = jnp.asarray([i for i, _ in take])
        if self._cache is None:
            self._cache = jax.tree_util.tree_map(
                lambda leaf: jnp.zeros(
                    (leaf.shape[0], self.max_batch) + leaf.shape[2:],
                    leaf.dtype), kv)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._cache = jax.tree_util.tree_map(
            lambda p, nw: p.at[:, rows].set(nw), self._cache, kv)
        self._logits = self._logits.at[rows].set(logits)
        now = wall_now()
        for i, r in take:
            r.started_at = now
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())

    # -------------------------------------------------------- paged admission
    def _pages(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def _admit_paged(self) -> None:
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        usable = self._num_pages - 1
        if resident and any(self._pages(self._required(r)) > usable
                            or (self.spec and self._draft_required(r)
                                > self._draft_capacity)
                            for r in self._queue):
            # a queued request cannot fit the pool (or the draft cache) even
            # alone; stop backfilling so the batch drains and the growth
            # branch runs
            return
        if not resident:
            # grow the pool while nothing is resident, so any single admitted
            # request can always run to completion (its worst-case pages fit
            # the pool) — this is what makes LIFO preemption livelock-free.
            # Growth reallocates every page, so it also forgets the prefix
            # cache and is deferred while handoff pins hold page content.
            needed = max(self._pages(self._required(r))
                         for r in self._queue[:self.max_batch])
            if (self._pools is None or needed > usable) \
                    and not self._pinned:
                self._num_pages = max(self._num_pages, needed + 1)
                usable = self._num_pages - 1
                self._pools = None
                self._logits = None
                self._free_pages = list(range(1, self._num_pages))
                self._flush_prefix_cache()
            if self.spec:
                # the draft cache is allocation-static under jit too: grow
                # it at the same idle points as the pool
                dneeded = max(self._draft_required(r)
                              for r in self._queue[:self.max_batch])
                if self._draft_cache is None \
                        or dneeded > self._draft_capacity:
                    self._draft_capacity = max(self._draft_capacity, dneeded)
                    self._draft_cache = None
        free_slots = [i for i, s in enumerate(self._slots) if s is None]
        # cold cached pages are evictable on demand, so they count as free —
        # but a cold page a taken request will *share* stops being evictable
        # (it revives to refcount 1), so it costs headroom exactly once
        free_now = len(self._free_pages) + len(self._cold)
        cold_reserved: set = set()
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        taking = resident
        for r in self._queue:
            hit_pages = (self._prefix_lookup_pages(r.tokens)
                         if self.prefix_cache else [])
            cold_cost = sum(1 for pg in hit_pages
                            if pg in self._cold and pg not in cold_reserved)
            suffix_tokens = len(r.tokens) - len(hit_pages) * self.page_size
            need = self._pages(suffix_tokens)
            if (free_slots and need + cold_cost <= free_now
                    and self._pages(self._required(r)) <= usable
                    and (not self.spec
                         or self._draft_required(r) <= self._draft_capacity)
                    and paged_admit_ok(free_now - cold_cost, suffix_tokens,
                                       self.page_size, resident=taking)):
                take.append((free_slots.pop(0), r))
                free_now -= need + cold_cost
                cold_reserved.update(pg for pg in hit_pages
                                     if pg in self._cold)
                taking = True
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._grow_block_tables(max(self._pages(self._required(r))
                                        for _, r in take))
            self._prefill_paged(take)

    def _grow_block_tables(self, maxp: int) -> None:
        if maxp <= self._maxp:
            return
        wider = np.zeros((self.max_batch, maxp), np.int32)
        wider[:, : self._maxp] = self._block_tables
        self._block_tables = wider
        self._maxp = maxp
        self._tables_dirty = True

    def _table_width(self, lookahead: int = 1) -> int:
        """Logical-page width the decode block table needs this step: every
        resident row's allocated pages, plus one column PAST the page its
        next ``lookahead`` writes land in.  The extra column matters for
        riding-along rows whose prompt exactly fills their pages: their
        inert write targets the next (unallocated) logical page, and
        without the column the clamped table lookup would alias slot 0 of
        their own last real page.  Rounded up to a power of two (few jit
        shapes), capped at the full table."""
        need = 1
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            last_write = (int(self._lengths[i]) + lookahead - 1)
            need = max(need, len(self._row_pages[i]),
                       last_write // self.page_size + 1)
        w = 1
        while w < need:
            w *= 2
        return min(w, self._maxp)

    def _prefill_paged(self, take: List[Tuple[int, GenRequest]]) -> None:
        """Prefill admitted rows into pool pages.  Rows with no cached
        prefix take the cold path (right-padded contiguous prefill, then a
        page scatter; pad-tail pages alias the scratch page 0, which
        per-row lengths keep inert).  With prefix caching, rows whose
        prompt head is already chain-resident pin the shared pages and
        compute only the uncached suffix via one multi-token verify
        forward (DESIGN.md §6.1-prefix)."""
        ps = self.page_size
        # All acquires happen before any register: rows admitted in the same
        # batch never share each other's fresh pages.  Allowing it would let
        # a warm row attend into pages another row is still writing inside
        # the same verify forward — sharing is cross-batch only.
        shared: Dict[int, List[int]] = {}
        for i, r in take:
            shared[i] = (self._prefix_acquire(np.asarray(r.tokens, np.int32))
                         if self.prefix_cache else [])
        for i, r in take:
            hits = len(shared[i])
            fresh = [self._claim_page()
                     for _ in range(self._pages(len(r.tokens)) - hits)]
            if self.prefix_cache:
                self._prefix_register(np.asarray(r.tokens, np.int32),
                                      hits, fresh)
            pages = shared[i] + fresh
            self._row_pages[i] = pages
            self._block_tables[i, :] = 0
            self._block_tables[i, : len(pages)] = pages
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)
            self._slot_seq[i] = self._admit_seq
            self._admit_seq += 1
        self._tables_dirty = True
        cold = [(i, r) for i, r in take if not shared[i]]
        warm = [(i, r) for i, r in take if shared[i]]
        if cold:
            self._prefill_cold(cold)
        if warm:
            self._prefill_warm(warm, {i: len(shared[i]) for i, _ in warm})
        now = wall_now()                # started_at matches the slot path:
        for _, r in take:               # stamped after prefill completes
            r.started_at = now
        self.stats.batches += 1
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())
        if self.prefix_cache:
            reg = get_registry()
            for i, r in take:
                cached = len(shared[i]) * ps
                p = max(1, len(r.tokens))
                self.prefix_lookup_tokens += p
                self.prefix_hit_tokens += cached
                self.prefix_hit_rate += PREFIX_HIT_EMA_BETA * (
                    cached / p - self.prefix_hit_rate)
                reg.counter("engine.prefix.lookup_tokens").inc(p)
                reg.counter("engine.prefix.hit_tokens").inc(cached)
        if self.spec:
            plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
            plen = -(-plen // ps) * ps
            toks = np.full((len(take), plen), self.eos_id, np.int32)
            last = np.zeros(len(take), np.int32)
            for j, (_, r) in enumerate(take):
                toks[j, : len(r.tokens)] = r.tokens
                last[j] = len(r.tokens) - 1
            self._spec_prefill_draft(take, toks, last)

    def _prefill_cold(self, cold: List[Tuple[int, GenRequest]]) -> None:
        """Right-padded prompt prefill, then scatter the contiguous KV into
        the rows' already-allocated pool pages."""
        n = len(cold)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in cold))
        plen = -(-plen // self.page_size) * self.page_size  # page multiple
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        phys = np.zeros((n, plen // self.page_size), np.int32)
        for j, (i, r) in enumerate(cold):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
            phys[j, : len(self._row_pages[i])] = self._row_pages[i]
        with get_tracer().wall("engine.prefill", who=self.owner, path="cold",
                               rows=n, tokens=plen * n) as sp:
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)},
                                          plen, jnp.asarray(last))
            logits.block_until_ready()
        self.stats.prefill_wall_s += sp.dt
        self.stats.prefill_tokens += plen * n
        kv = {k: v for k, v in cache.items() if k != "length"}
        if self._pools is None:
            self._pools = self._init_pools(self.cfg, self._num_pages,
                                           self.page_size)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._pools = self._scatter_pages(self._pools, kv, jnp.asarray(phys))
        rows = jnp.asarray([i for i, _ in cold])
        self._logits = self._logits.at[rows].set(logits)

    def _prefill_warm(self, warm: List[Tuple[int, GenRequest]],
                      hits: Dict[int, int]) -> None:
        """Cached-suffix prefill (DESIGN.md §6.1-prefix): warm rows enter
        with ``_lengths`` temporarily set to their cached token count, and
        ONE batched multi-token verify forward computes the uncached
        suffix attending to the shared prefix pages — same kernel, same
        rider semantics as a speculative verify: non-warm rows' inert
        writes land on the scratch page or beyond their valid length, and
        their carried logits are untouched."""
        ps = self.page_size
        assert self._pools is not None   # a chain hit implies prior prefills
        suf_lens = {i: len(r.tokens) - hits[i] * ps for i, r in warm}
        S = -(-max(suf_lens.values()) // ps) * ps    # page-rounded jit width
        toks = np.full((self.max_batch, S), self.eos_id, np.int32)
        for i, r in warm:
            toks[i, : suf_lens[i]] = np.asarray(r.tokens[hits[i] * ps:],
                                                np.int32)
            self._lengths[i] = hits[i] * ps  # valid tokens = cached prefix
        # every rider row (including cold rows prefilled this round) writes
        # at lengths + j for j < S; the table must be wide enough that
        # those lookups hit a zero entry -> scratch, never a real page
        need_w = max((int(self._lengths[i]) + S - 1) // ps + 1
                     for i, s in enumerate(self._slots) if s is not None)
        self._grow_block_tables(need_w)
        w = self._table_width(lookahead=S)
        cache = {**self._pools,
                 "block_tables": jnp.asarray(self._block_tables[:, :w]),
                 "lengths": jnp.asarray(self._lengths, jnp.int32)}
        with get_tracer().wall("engine.prefill", who=self.owner, path="warm",
                               rows=len(warm), tokens=S * len(warm),
                               cached_pages=sum(hits.values())) as sp:
            vlogits, cache = self._verify(self.params, cache,
                                          jnp.asarray(toks))
            vlogits.block_until_ready()
        self.stats.prefill_wall_s += sp.dt
        self.stats.prefill_tokens += S * len(warm)
        self._pools = {n: cache[n] for n in self._pool_names}
        self._tables_dirty = True
        rows = jnp.asarray([i for i, _ in warm])
        pos = jnp.asarray([suf_lens[i] - 1 for i, _ in warm])
        self._logits = self._logits.at[rows].set(vlogits[rows, pos][:, None])
        for i, r in warm:
            self._lengths[i] = len(r.tokens)

    # ------------------------------------------------- prefix cache internals
    # (DESIGN.md §6.1-prefix) — content-addressed pages with holder
    # refcounts; the chain, cold LRU, and free list partition the pool.

    def _chain_hashes(self, tokens: np.ndarray) -> List[int]:
        """Cumulative page-aligned content hashes over the prompt's full
        pages: ``h_i = crc32(page_i, h_{i-1})``.  A prefix match is a
        chain walk, so two prompts share pages exactly up to their first
        differing page — copy-on-write at page granularity (a mid-page
        divergence is a miss at that depth, never a partial-page share)."""
        arr = np.ascontiguousarray(np.asarray(tokens, np.int32))
        ps = self.page_size
        out: List[int] = []
        h = 0
        for i in range(len(arr) // ps):
            h = zlib.crc32(arr[i * ps:(i + 1) * ps].tobytes(), h)
            out.append(h)
        return out

    def _prefix_lookup_pages(self, tokens: np.ndarray) -> List[int]:
        """Dry chain walk: the cached pages a prompt would reuse, capped by
        the shared hit rule (no refcounts move — ``_prefix_acquire`` claims
        at prefill time)."""
        hashes = self._chain_hashes(np.asarray(tokens, np.int32))
        matched = 0
        for h in hashes:
            if h not in self._chain:
                break
            matched += 1
        hits = prefix_hit_pages(len(tokens), self.page_size,
                                matched * self.page_size)
        return [self._chain[h] for h in hashes[:hits]]

    def _prefix_acquire(self, tokens: np.ndarray) -> List[int]:
        """Claim the cached prefix pages for a row about to prefill: bump
        holder refcounts (reviving cold pages out of the eviction LRU) and
        return them in chain order, capped by the shared hit rule."""
        hashes = self._chain_hashes(tokens)
        matched = 0
        for h in hashes:
            if h not in self._chain:
                break
            matched += 1
        hits = prefix_hit_pages(len(tokens), self.page_size,
                                matched * self.page_size)
        pages: List[int] = []
        for h in hashes[:hits]:
            pg = self._chain[h]
            if pg in self._cold:
                del self._cold[pg]
            self._page_ref[pg] = self._page_ref.get(pg, 0) + 1
            pages.append(pg)
        if pages and hashes[0] in self._head_lru:
            self._head_lru.move_to_end(hashes[0])
        return pages

    def _prefix_register(self, tokens: np.ndarray, hits: int,
                         fresh: List[int]) -> None:
        """Enter a row's freshly computed FULL prompt pages into the
        content chain so later requests can share them.  Partial tail
        pages stay private (decode keeps writing into them), as does any
        page whose chain hash is already taken by another physical page
        (first writer wins; the duplicate stays an unshared holder)."""
        hashes = self._chain_hashes(tokens)
        if hits and hashes[0] in self._head_lru:
            self._head_lru.move_to_end(hashes[0])
        for j in range(hits, len(hashes)):
            h = hashes[j]
            pg = fresh[j - hits]
            if h in self._chain or pg in self._page_hash:
                continue
            self._chain[h] = pg
            self._page_hash[pg] = h
            if j == 0:
                self._head_lru[h] = None
                self._head_lru.move_to_end(h)

    def _claim_page(self) -> int:
        """One page for a row to hold: the free list first, then evict the
        LRU cold cached page (cold pages have refcount 0 by construction —
        warm pages are never eviction candidates)."""
        if self._free_pages:
            pg = self._free_pages.pop()
        else:
            pg, _ = self._cold.popitem(last=False)
            self._evict_entry(pg)
        if self.prefix_cache:
            self._page_ref[pg] = 1
        return pg

    def _evict_entry(self, pg: int) -> None:
        h = self._page_hash.pop(pg, None)
        if h is not None:
            self._chain.pop(h, None)
            self._head_lru.pop(h, None)

    def _drop_page(self, pg: int) -> None:
        """One holder lets go of a page.  Refcounted pages go *cold* at
        zero holders when chain-registered — still content-addressable,
        LRU-evictable — else back to the free list; unrefcounted pages
        (prefix cache off) free directly."""
        ref = self._page_ref.get(pg)
        if ref is None:
            self._free_pages.append(pg)
            return
        if ref > 1:
            self._page_ref[pg] = ref - 1
            return
        del self._page_ref[pg]
        if pg in self._page_hash:
            self._cold[pg] = None           # lands at the MRU end
        else:
            self._free_pages.append(pg)

    def _flush_prefix_cache(self) -> None:
        """Pool reallocation invalidates every page's content: forget the
        chain and the cold set (callers reset the free list)."""
        self._chain.clear()
        self._page_hash.clear()
        self._page_ref.clear()
        self._cold.clear()
        self._head_lru.clear()

    def debug_page_accounting(self) -> Dict[str, int]:
        """Reconcile the free list, cold cache, refcounts, and row/pin
        holdings (the §6.1-prefix conservation invariant, exercised by the
        churn tests): every usable page is exactly one of free, cold, or
        held; shared pages are counted once; per-page refcounts equal the
        number of holders."""
        assert self.paged
        usable = self._num_pages - 1
        free = set(self._free_pages)
        cold = set(self._cold)
        held: Dict[int, int] = {}
        for pages in self._row_pages:
            for pg in pages:
                held[pg] = held.get(pg, 0) + 1
        for pages in self._pinned.values():
            for pg in pages:
                held[pg] = held.get(pg, 0) + 1
        assert len(free) == len(self._free_pages), "free list has duplicates"
        assert not free & cold, "page both free and cold-cached"
        assert not free & set(held), "page both free and row-held"
        assert not cold & set(held), "page both cold and row-held"
        for pg, n in held.items():
            ref = self._page_ref.get(pg)
            if ref is not None:
                assert ref == n, f"page {pg}: refcount {ref} != holders {n}"
            else:
                assert n == 1, f"untracked page {pg} shared by {n} holders"
        every = free | cold | set(held)
        assert every <= set(range(1, usable + 1)), "page id out of range"
        assert len(free) + len(cold) + len(held) == usable, (
            f"page leak/double-free: {len(free)} free + {len(cold)} cold "
            f"+ {len(held)} held != {usable} usable")
        return {"free": len(free), "cold": len(cold), "held": len(held)}

    def prefix_pin(self, req: GenRequest) -> int:
        """Decode-side cache consultation for a disagg handoff (DESIGN.md
        §6.1-prefix): walk the chain for ``req``'s prompt, claim the
        matched pages NOW (so they cannot be evicted while the handoff is
        on the wire), remember them under the request id, and return the
        cached token count — the prefill side then neither gathers nor
        byte-counts those pages.  Returns 0 when caching is off, the pool
        is unallocated, the request is already pinned, or it would force a
        pool growth (growth reallocates every page, which would strand the
        pin)."""
        if (not self.prefix_cache or self._pools is None
                or req.rid in self._pinned
                or self._pages(self._required(req)) > self._num_pages - 1):
            return 0
        pages = self._prefix_acquire(np.asarray(req.tokens, np.int32))
        p = max(1, len(req.tokens))
        cached = len(pages) * self.page_size
        self.prefix_lookup_tokens += p
        self.prefix_hit_tokens += cached
        reg = get_registry()
        reg.counter("engine.prefix.lookup_tokens").inc(p)
        reg.counter("engine.prefix.hit_tokens").inc(cached)
        self.prefix_hit_rate += PREFIX_HIT_EMA_BETA * (
            cached / p - self.prefix_hit_rate)
        if not pages:
            return 0
        self._pinned[req.rid] = pages
        return cached

    def _spec_prefill_draft(self, take: List[Tuple[int, GenRequest]],
                            toks: np.ndarray, last: np.ndarray) -> None:
        """Run the draft model's prefill over the same right-padded prompts
        and install its contiguous KV rows next to the target's slots
        (DESIGN.md §6.1-spec).  The draft's prompt logits are discarded:
        drafting always starts by feeding the pending token."""
        with get_tracer().wall("engine.spec_draft", who=self.owner,
                               path="prefill", rows=len(take)) as sp:
            dlogits, dcache = self._draft_prefill(
                self.spec_draft_params, {"tokens": jnp.asarray(toks)},
                self._draft_capacity, jnp.asarray(last))
            dlogits.block_until_ready()
        self.stats.draft_wall_s += sp.dt
        dkv = {k: v for k, v in dcache.items() if k != "length"}
        if self._draft_cache is None:
            self._draft_cache = jax.tree_util.tree_map(
                lambda leaf: jnp.zeros(
                    (leaf.shape[0], self.max_batch) + leaf.shape[2:],
                    leaf.dtype), dkv)
        rows = jnp.asarray([i for i, _ in take])
        self._draft_cache = jax.tree_util.tree_map(
            lambda p, nw: p.at[:, rows].set(nw), self._draft_cache, dkv)
        for i, r in take:
            self._draft_lengths[i] = len(r.tokens)

    # ----------------------------------------------------- page pool dynamics
    def _release_pages(self, i: int) -> None:
        for pg in self._row_pages[i]:
            self._drop_page(pg)
        self._row_pages[i] = []
        self._block_tables[i, :] = 0
        self._tables_dirty = True

    def _preempt(self, i: int) -> None:
        """Reclaim row ``i``'s pages and requeue its request at the head of
        the queue (vLLM-style recompute preemption: generated tokens are
        discarded; the greedy restart reproduces them bit-identically).

        The admission clocks are reset along with the discarded tokens:
        ``started_at``/``first_token_at`` belong to the aborted attempt, so
        leaving them set would let a mid-flight reader (metrics scrape, the
        disagg executor re-routing the request) report a TTFT for tokens
        the user never kept.  The restart re-stamps both, which also keeps
        ``enqueued_at <= started_at <= first_token_at <= finished_at``
        monotone on the completion record."""
        r = self._slots[i].req
        r.result = None
        r.started_at = 0.0
        r.first_token_at = 0.0
        self._release_pages(i)
        self._slots[i] = None
        self._lengths[i] = 0
        if self.spec:
            # the draft row is re-prefilled from scratch on re-admission
            self._draft_lengths[i] = 0
        self._queue.insert(0, r)
        self.stats.preempted += 1
        get_registry().counter("engine.preempted").inc()
        tr = get_tracer()
        if tr.enabled:
            tr.event("executor.preempt", r.rid, self.owner, wall_now(),
                     clock=WALL, row=i)

    def _ensure_decode_pages(self, survivors: List[int],
                             lookahead: int = 1) -> List[int]:
        """Allocate pages covering the next ``lookahead`` write positions
        for every surviving row (1 for plain decode; ``spec_k + 1`` for a
        speculative verify, which writes the pending token plus k drafts).
        Under pool pressure the most recently admitted resident is
        preempted until a page frees; oldest rows are served first, so the
        oldest admission always makes progress and the preemption loop
        terminates."""
        for i in sorted(survivors, key=lambda i: self._slot_seq[i]):
            while (self._slots[i] is not None
                   and (self._lengths[i] + lookahead - 1) // self.page_size
                   >= len(self._row_pages[i])):
                if self._free_pages or self._cold:
                    pg = self._claim_page()
                    self._row_pages[i].append(pg)
                    idx = len(self._row_pages[i]) - 1
                    self._grow_block_tables(idx + 1)
                    self._block_tables[i, idx] = pg
                    self._tables_dirty = True
                else:
                    victims = [j for j, s in enumerate(self._slots)
                               if s is not None]
                    self._preempt(max(victims, key=lambda j:
                                      self._slot_seq[j]))
        return [i for i in survivors if self._slots[i] is not None]

    # ------------------------------------------- disaggregated KV handoff
    # (DESIGN.md §6.1-disagg) — both ends live here because the page pool,
    # block tables, and free list are private to the engine (grep-guarded).

    def extract_handoffs(self, cached_tokens_fn: Optional[
            Callable[[GenRequest], int]] = None) -> List[KVHandoff]:
        """Disagg prefill side: pop every resident row that has sampled at
        least one token as a ``KVHandoff`` and release its local pages.

        Driven after each ``step()`` of a prefill-role engine: a freshly
        admitted row samples its first token and decodes it (writing its KV)
        within that same step, so no row ever survives two steps here — the
        prefill engine's pool only ever holds prompts mid-prefill.  The
        gathered ``k``/``v`` are copies, which is what the simulated
        transfer cost model charges for.

        ``cached_tokens_fn`` is the decode side's ``prefix_pin`` (DESIGN.md
        §6.1-prefix): it returns how many prompt tokens the decode engine
        already holds cached (a page multiple, pinned against eviction);
        those leading pages are neither gathered nor counted in
        ``handoff_bytes`` on either end.
        """
        assert self.paged, "KV handoff requires the paged backend"
        assert not self.spec, "KV handoff and speculative decoding are " \
            "separate backends (the draft cache does not travel)"
        assert not self.cfg.kv_quant, "KV handoff carries fp pages only " \
            "(quantized scale pools do not travel; DESIGN.md §6.1-paged)"
        out: List[KVHandoff] = []
        for i, s in enumerate(self._slots):
            if s is None or not s.out:
                continue
            cached = int(cached_tokens_fn(s.req)) if cached_tokens_fn else 0
            pages = jnp.asarray(
                self._row_pages[i][cached // self.page_size:], jnp.int32)
            h = KVHandoff(
                req=s.req, out=list(s.out), length=int(self._lengths[i]),
                k=self._pools["k_pool"][:, pages],
                v=self._pools["v_pool"][:, pages],
                logits=self._logits[i], page_size=self.page_size,
                cached_tokens=cached)
            self._release_pages(i)
            self._slots[i] = None
            self._lengths[i] = 0
            self.stats.handoffs += 1
            self.stats.handoff_bytes += h.kv_bytes
            out.append(h)
        return out

    def accept_handoff(self, h: KVHandoff) -> bool:
        """Disagg decode side: allocate pages for a handed-off request,
        scatter its KV into this engine's pool, and install it in a free
        slot with its prefill logits — decode resumes exactly where the
        prefill engine stopped, so greedy outputs stay bit-identical to a
        colocated paged engine.  Returns False (caller retries after a
        completion) when no slot or not enough free pages are available.
        """
        assert self.paged and h.page_size == self.page_size
        assert not self.spec, "KV handoff and speculative decoding are " \
            "separate backends (the draft cache does not travel)"
        assert not self.cfg.kv_quant, "KV handoff carries fp pages only " \
            "(quantized scale pools do not travel; DESIGN.md §6.1-paged)"
        free_slots = [i for i, s in enumerate(self._slots) if s is None]
        if not free_slots:
            return False
        resident = any(s is not None for s in self._slots)
        usable = self._num_pages - 1
        worst = self._pages(self._required(h.req))
        if not resident:
            # grow the pool while nothing is resident (mirror _admit_paged)
            # so any single accepted handoff can always run to completion —
            # deferred while handoff pins hold page content, since growth
            # reallocates every page and forgets the prefix cache
            if (self._pools is None or worst > usable) \
                    and not self._pinned:
                self._num_pages = max(self._num_pages, worst + 1)
                usable = self._num_pages - 1
                self._pools = None
                self._logits = None
                self._free_pages = list(range(1, self._num_pages))
                self._flush_prefix_cache()
        if worst > usable:
            return False               # can never fit: wait for drain+growth
        pinned = self._pinned.get(h.req.rid, [])
        assert len(pinned) * self.page_size == h.cached_tokens, \
            "handoff was sliced against a pin this engine no longer holds"
        need = pages_for(h.length, self.page_size)
        fresh_need = need - len(pinned)
        if fresh_need > len(self._free_pages) + len(self._cold):
            return False               # keep the pin; caller retries
        self._pinned.pop(h.req.rid, None)
        if self._pools is None:
            self._pools = self._init_pools(self.cfg, self._num_pages,
                                           self.page_size)
            self._logits = jnp.zeros(
                (self.max_batch, 1, h.logits.shape[-1]), h.logits.dtype)
        i = free_slots[0]
        fresh = [self._claim_page() for _ in range(fresh_need)]
        if fresh:
            phys = jnp.asarray(fresh, jnp.int32)
            self._pools = {
                "k_pool": self._pools["k_pool"].at[:, phys].set(
                    h.k[:, :fresh_need]),
                "v_pool": self._pools["v_pool"].at[:, phys].set(
                    h.v[:, :fresh_need])}
        pages = pinned + fresh
        if self.prefix_cache:
            # the transported full prompt pages are now valid content:
            # register them so later requests (and later handoffs, via
            # prefix_pin) can share them
            self._prefix_register(np.asarray(h.req.tokens, np.int32),
                                  len(pinned), fresh)
        self._grow_block_tables(max(need, worst))
        self._row_pages[i] = pages
        self._block_tables[i, :] = 0
        self._block_tables[i, :need] = pages
        self._tables_dirty = True
        slot = _Slot(h.req)
        slot.out = list(h.out)
        self._slots[i] = slot
        self._lengths[i] = h.length
        self._slot_seq[i] = self._admit_seq
        self._admit_seq += 1
        self._logits = self._logits.at[i].set(h.logits)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += h.kv_bytes
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())
        return True

    # ------------------------------------------------------------ decode step
    def _append_token(self, i: int, t: int, now: float,
                      finished: List[GenRequest]) -> bool:
        """Append one emitted token to row ``i``, retiring the row on EOS
        or budget exhaustion (shared by the plain sampling phase and the
        speculative acceptance loop, so multi-token emission keeps the
        exact single-token semantics: EOS is dropped from the result
        unless it is the only token).  Returns True while the row
        survives."""
        slot = self._slots[i]
        slot.out.append(t)
        if len(slot.out) == 1:
            slot.req.first_token_at = now
        hit_eos = t == self.eos_id
        if hit_eos or len(slot.out) >= slot.req.max_new:
            row = slot.out[:-1] if hit_eos and len(slot.out) > 1 \
                else slot.out
            slot.req.result = np.asarray(row, np.int32)
            slot.req.finished_at = now
            finished.append(slot.req)
            self._slots[i] = None
            if self.paged:
                self._release_pages(i)         # pages return to the pool
            self.stats.served += 1
            return False
        return True

    def step(self) -> List[GenRequest]:
        """One engine iteration: sample a token for every resident sequence,
        retire finished ones, prefill admissions into freed slots, then run
        one batched decode step for the sequences that continue."""
        if not self.slot_decode:
            return self._step_wave_legacy()
        if self.spec:
            return self._step_spec()
        self._admit()
        resident = [i for i, s in enumerate(self._slots) if s is not None]
        if not resident:
            return []
        # 1. sample next token for all resident rows from their current logits
        self.key, sk = jax.random.split(self.key)
        temps_np = np.zeros(self.max_batch, np.float32)
        for i in resident:
            temps_np[i] = self._slots[i].req.temperature
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        cur = sample(sk, self._logits, temperature=temps,
                     vocab_size=self.cfg.vocab_size)
        cur_np = np.asarray(cur[:, 0])
        now = wall_now()
        finished: List[GenRequest] = []
        survivors: List[int] = []
        for i in resident:
            if self._append_token(i, int(cur_np[i]), now, finished):
                survivors.append(i)
        # 2. admit queued work into freed slots between decode steps
        if self.continuous and finished:
            self._admit()
        # 2b. paged: claim this step's write page per survivor, preempting
        #     the most recent admissions if the pool is exhausted
        if self.paged and survivors:
            survivors = self._ensure_decode_pages(survivors)
        # 3. one batched decode step advances the surviving rows; rows that
        #    were empty or just prefilled ride along (static batch shape) —
        #    their cache write lands at their own depth and is overwritten by
        #    their first real decode, and their logits are kept, not replaced
        if survivors:
            with get_tracer().wall("engine.decode_step", who=self.owner,
                                   batch=len(survivors)) as spn:
                if self.paged:
                    # trim the table to the pages live rows can actually
                    # touch and reuse the device-resident copy whenever no
                    # host-side mutation invalidated it (§Perf-kernels)
                    w = self._table_width()
                    if (self._tables_dirty or self._bt_dev is None
                            or self._bt_dev.shape[1] != w):
                        self._bt_dev = jnp.asarray(self._block_tables[:, :w])
                        self._len_dev = jnp.asarray(self._lengths, jnp.int32)
                    cache = {**self._pools, "block_tables": self._bt_dev,
                             "lengths": self._len_dev}
                    logits, cache = self._decode_paged(self.params, cache,
                                                       cur)
                    logits.block_until_ready()
                    self._pools = {n: cache[n] for n in self._pool_names}
                    # the cache is donated: only the RETURNED tables/lengths
                    # are valid now.  They advanced every row by one; reuse
                    # is only sound when every active row was a survivor — a
                    # rider row (admitted mid-step) holds its prompt length
                    # on the host but length+1 on the device, so its next
                    # write would skip a position.  Any rider forces a
                    # re-upload.
                    self._bt_dev = cache["block_tables"]
                    self._len_dev = cache["lengths"]
                    self._tables_dirty = self.active_slots() != len(survivors)
                else:
                    cache = {**self._cache,
                             "length": jnp.asarray(self._lengths, jnp.int32)}
                    logits, cache = self._decode(self.params, cache, cur)
                    logits.block_until_ready()
                    self._cache = {k: v for k, v in cache.items()
                                   if k != "length"}
            self.stats.decode_wall_s += spn.dt
            keep = jnp.asarray(survivors)
            self._logits = self._logits.at[keep].set(logits[keep])
            self._lengths[survivors] += 1
            self.stats.decode_tokens += len(survivors)
            self.stats.decode_steps += 1
        return finished

    # ------------------------------------------------- speculative decoding
    def _step_spec(self) -> List[GenRequest]:
        """One speculative engine iteration (DESIGN.md §6.1-spec).

        The pending token is sampled for every resident row from its
        carried logits exactly as the plain paged step does; then, instead
        of one single-token decode, the draft model proposes ``spec_k``
        tokens greedily and ONE batched target forward
        (``Family.paged_verify``) scores pending + drafts at once.  The
        longest draft prefix matching the target's own greedy choices is
        emitted; the correction token is NOT emitted here — the verify
        logits after the last accepted token become the carried logits, so
        the next iteration's sampling phase reproduces it.  Every emitted
        token is therefore the argmax of target logits over the same
        prefix as non-speculative decode: greedy outputs are
        bit-identical, speculation only changes how many target forwards
        they take.
        """
        self._admit()
        resident = [i for i, s in enumerate(self._slots) if s is not None]
        if not resident:
            return []
        # 1. pending token from carried logits (identical to the base step;
        #    spec rows are greedy-only, enforced at submit)
        self.key, sk = jax.random.split(self.key)
        cur = sample(sk, self._logits, temperature=0.0,
                     vocab_size=self.cfg.vocab_size)
        cur_np = np.asarray(cur[:, 0])
        now = wall_now()
        finished: List[GenRequest] = []
        survivors: List[int] = []
        for i in resident:
            if self._append_token(i, int(cur_np[i]), now, finished):
                survivors.append(i)
        # 2. admit queued work into freed slots between steps (freshly
        #    prefilled rows ride along this verify and join the next one)
        if self.continuous and finished:
            self._admit()
        # 2b. claim pages covering the pending token + spec_k draft writes,
        #     preempting the most recent admissions if the pool exhausts
        if survivors:
            survivors = self._ensure_decode_pages(survivors,
                                                  lookahead=self.spec_k + 1)
        if not survivors:
            return finished
        k = self.spec_k
        # 3. draft k tokens greedily, feeding the pending token first; the
        #    draft cache rows advance in lock-step with the target's pages
        #    (riding-along rows write garbage at their own stale depth,
        #    fully overwritten before it is ever attended)
        drafts = np.zeros((self.max_batch, k), np.int32)
        tok = cur
        with get_tracer().wall("engine.spec_draft", who=self.owner,
                               k=k, batch=len(survivors)) as dsp:
            for j in range(k):
                dcache = {**self._draft_cache,
                          "length": jnp.asarray(self._draft_lengths + j,
                                                jnp.int32)}
                dlogits, dcache = self._draft_decode(self.spec_draft_params,
                                                     dcache, tok)
                dlogits.block_until_ready()
                self._draft_cache = {n: v for n, v in dcache.items()
                                     if n != "length"}
                tok = _greedy_tokens(dlogits[:, -1],
                                     self.spec_draft_cfg.vocab_size)[:, None]
                drafts[:, j] = np.asarray(tok[:, 0])
            # land the last draft's KV too: each proposing forward writes
            # its INPUT token, so d_k would be missing from the draft cache
            # when all k drafts are accepted and the next round builds on it
            # — one discarded forward writes it at draft position n + k
            # (harmless for rows that accept less: the position is past
            # their valid prefix and overwritten before it is ever attended)
            dcache = {**self._draft_cache,
                      "length": jnp.asarray(self._draft_lengths + k,
                                            jnp.int32)}
            dlogits, dcache = self._draft_decode(self.spec_draft_params,
                                                 dcache, tok)
            dlogits.block_until_ready()
            self._draft_cache = {n: v for n, v in dcache.items()
                                 if n != "length"}
        self.stats.draft_wall_s += dsp.dt
        self.stats.spec_drafted += k * len(survivors)
        # 4. verify pending + drafts in ONE batched target forward; the
        #    verify scatters all k+1 tokens' KV into the pages claimed in
        #    2b (rejected drafts land beyond the valid length and are
        #    overwritten by the next verify at the same positions)
        toks = np.concatenate([cur_np[:, None], drafts], axis=1)
        # spec lengths advance by a variable 1+a per row, so the device
        # tables are rebuilt every verify (no resident reuse); the width is
        # still trimmed to the pages the k+1 writes can touch
        w = self._table_width(lookahead=self.spec_k + 1)
        cache = {**self._pools,
                 "block_tables": jnp.asarray(self._block_tables[:, :w]),
                 "lengths": jnp.asarray(self._lengths, jnp.int32)}
        with get_tracer().wall("engine.spec_verify", who=self.owner,
                               k=k, batch=len(survivors)) as vsp:
            vlogits, cache = self._verify(self.params, cache,
                                          jnp.asarray(toks))
            vlogits.block_until_ready()
        self.stats.decode_wall_s += vsp.dt
        self.stats.verify_wall_s += vsp.dt
        self._pools = {n: cache[n] for n in self._pool_names}
        # the target's greedy choice at every position, with the same
        # vocab masking + argmax as sample(temperature=0)
        tgt = np.asarray(_greedy_tokens(vlogits, self.cfg.vocab_size))
        # 5. per row: accept the longest draft prefix matching the target,
        #    emit it under the usual EOS/budget rules, advance the caches
        #    over pending + accepted tokens only
        now = wall_now()
        rows: List[int] = []
        pos: List[int] = []
        accepts: List[int] = []
        for i in survivors:
            a = 0
            while a < k and drafts[i, a] == tgt[i, a]:
                a += 1
            self.spec_accept_hist[a] += 1
            self.stats.spec_accepted += a
            accepts.append(a)
            appended = 0
            alive = True
            for j in range(a):
                appended += 1
                if not self._append_token(i, int(drafts[i, j]), now,
                                          finished):
                    alive = False
                    break
            # count tokens fed to a target forward as valid context — the
            # same rule the plain path's len(survivors) implements: a
            # request's FINAL emitted token (here: the draft that retired
            # the row) never feeds a forward, so both engines accumulate
            # identical decode_tokens for identical outputs
            self.stats.decode_tokens += appended + (1 if alive else 0)
            if alive:
                self._lengths[i] += 1 + a
                self._draft_lengths[i] = self._lengths[i]
                rows.append(i)
                pos.append(a)       # carry logits after the last accepted
        # ONE EMA update per verify step (the documented SPEC_EMA_BETA
        # semantics), over the step's mean acceptance — per-row updates
        # would scale the effective smoothing with batch size
        obs = sum(accepts) / (k * len(accepts))
        self.spec_alpha += SPEC_EMA_BETA * (obs - self.spec_alpha)
        # 6. carry each surviving row's correction logits: position a is the
        #    target's distribution after [pending, d_1..d_a] — next step's
        #    argmax emits the correction (or the bonus token when a == k)
        if rows:
            ridx = jnp.asarray(rows)
            upd = vlogits[ridx, jnp.asarray(pos)][:, None]
            self._logits = self._logits.at[ridx].set(upd)
        self.stats.decode_steps += 1
        self.stats.spec_steps += 1
        return finished

    # ----------------------------------------------- legacy wave (non-dense)
    def _step_wave_legacy(self) -> List[GenRequest]:
        if not self._queue:
            return []
        wave, self._queue = (self._queue[: self.max_batch],
                             self._queue[self.max_batch:])
        return self._generate_wave(wave)

    def _serve_wave_legacy(self, reqs: List[GenRequest]) -> List[GenRequest]:
        out: List[GenRequest] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self._generate_wave(reqs[i: i + self.max_batch]))
        return out

    def _generate_wave(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Left-padded lock-step decode for families without per-row cache
        depths (shared scalar cache length)."""
        assert len(reqs) <= self.max_batch
        max_prompt = max(len(r.tokens) for r in reqs)
        plen = self._pad_bucket(max_prompt)
        max_new = max(r.max_new for r in reqs)
        toks = np.full((len(reqs), plen), self.eos_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.tokens):] = r.tokens     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cap = plen + self._pad_bucket(max_new)
        with get_tracer().wall("engine.prefill", who=self.owner, path="wave",
                               rows=len(reqs),
                               tokens=plen * len(reqs)) as sp:
            logits, cache = self._prefill(self.params, batch, cap)
            logits.block_until_ready()
        self.stats.prefill_wall_s += sp.dt
        self.stats.prefill_tokens += plen * len(reqs)
        self.stats.batches += 1
        started = wall_now()
        for r in reqs:
            r.started_at = started

        out = np.zeros((len(reqs), max_new), np.int32)
        done = np.zeros(len(reqs), bool)
        temps_np = np.array([r.temperature for r in reqs], np.float32)
        # all-greedy batches (the default) keep the scalar fast path in
        # sample(), skipping the per-step Gumbel draw over the vocab
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        budgets = np.array([r.max_new for r in reqs])
        for step in range(max_new):
            self.key, sk = jax.random.split(self.key)
            cur = sample(sk, logits, temperature=temps,
                         vocab_size=self.cfg.vocab_size)
            out[:, step] = np.asarray(cur[:, 0])
            if step == 0:
                now = wall_now()
                for r in reqs:
                    r.first_token_at = now
            done |= out[:, step] == self.eos_id
            done |= step + 1 >= budgets
            if done.all():
                break
            with get_tracer().wall("engine.decode_step", who=self.owner,
                                   batch=int((~done).sum())) as sp:
                logits, cache = self._decode(self.params, cache, cur)
                logits.block_until_ready()
            self.stats.decode_wall_s += sp.dt
            self.stats.decode_tokens += int((~done).sum())
            self.stats.decode_steps += 1
        for i, r in enumerate(reqs):
            row = out[i, : r.max_new]
            end = np.argmax(row == self.eos_id) if (row ==
                                                    self.eos_id).any() \
                else r.max_new
            r.result = row[: max(int(end), 1)]
            r.finished_at = wall_now()
        self.stats.served += len(reqs)
        return reqs

    def logprob_of(self, tokens: np.ndarray) -> float:
        """Sequence log-likelihood under this engine's model — used by the
        real-engine duel judges (DESIGN.md §6.2)."""
        t = jnp.asarray(tokens[None, :])
        logits = registry.apply_logits(self.params, self.cfg,
                                       {"tokens": t[:, :-1]},
                                       q_chunk=256, kv_chunk=256)
        logits = logits.astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(lp, t[:, 1:, None], axis=-1)
        return float(jnp.sum(gold))
