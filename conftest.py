"""Root pytest conftest: path bootstrap + offline property-test support.

Two jobs, both before any test module is imported:

1.  Make ``repro`` importable even when the caller forgot
    ``PYTHONPATH=src`` (the tier-1 command sets it; IDEs often don't).
2.  If the real ``hypothesis`` package is not installed (this container is
    offline), install the deterministic shim from
    ``repro.compat.hypothesis_shim`` under the ``hypothesis`` /
    ``hypothesis.strategies`` module names.  When hypothesis IS installed
    it is preferred untouched — delete the shim entries from
    ``sys.modules`` and re-run to compare engines.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
# benchmarks/ is imported as a package by test_sim_and_engine
_ROOT = os.path.dirname(os.path.abspath(__file__))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

try:
    import hypothesis  # noqa: F401 — real package wins when present
except ImportError:
    from repro.compat import hypothesis_shim as _shim

    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies
