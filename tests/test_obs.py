"""repro.obs: tracer, metrics registry, exporters, latency partition.

Four layers:

1.  ``Tracer``/``WallSpan`` unit tests — a disabled tracer is a no-op,
    ``Tracer.wall`` ALWAYS measures (the ``EngineStats`` accumulators
    depend on ``dt`` with tracing off) but only records when enabled.
2.  ``MetricsRegistry`` unit tests — labeled series, snapshot shape,
    type-conflict rejection — plus the ``core.network`` event accounting
    (satellite of DESIGN.md §Observability): queued-request drops feed
    both ``msg_counts["dropped"]`` and the labeled registry counter.
3.  Export tests — Chrome ``trace_event`` structure (two clock-domain
    processes, complete vs instant phases) and the latency breakdown.
4.  The end-to-end partition: a traced sim run's merged per-request
    sim spans reconstruct ``CompletedRequest.latency`` (the ``--trace``
    acceptance invariant), plus the ``MetricsCollector`` aggregate
    regressions that rode along with this plane.

Note: ``Span`` is deliberately never constructed here — the
``obs-lint/span-construction`` rule covers tests/ too, so spans are made
the idiomatic way, through the ``Tracer`` recording API.
"""

import json

import pytest

from repro.obs import (SIM, WALL, Histogram, MetricsRegistry, Tracer,
                       breakdown_report, get_registry, get_tracer,
                       latency_breakdown, set_registry, set_tracer,
                       to_chrome_trace, wall_now, write_chrome_trace)
from repro.sim.metrics import CompletedRequest, MetricsCollector


# ---------------------------------------------------------------------------
# 1. tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_disabled_tracer_records_nothing(self):
        tr = Tracer(enabled=False)
        tr.span("route.decide", "r1", "n0", 0.0, 1.0)
        tr.event("executor.admit", "r1", "n0", 1.0)
        with tr.wall("engine.decode_step", who="n0"):
            pass
        assert tr.spans == []

    def test_enabled_tracer_records_spans_and_events(self):
        tr = Tracer()
        tr.span("route.decide", "r1", "n0", 0.5, 1.5, mode="gossip",
                target="n2")
        tr.event("executor.admit", "r1", "n2", 1.5, active=3)
        a, b = tr.spans
        assert (a.name, a.rid, a.who, a.t0, a.t1) == \
            ("route.decide", "r1", "n0", 0.5, 1.5)
        assert a.clock == SIM and a.attrs["target"] == "n2"
        assert a.dur == 1.0
        assert b.t0 == b.t1 == 1.5 and b.attrs == {"active": 3}

    def test_wall_span_always_measures_records_only_when_enabled(self):
        # dt must be a real measurement even with tracing off: the
        # serving layer's EngineStats accumulators are fed from it
        for enabled in (False, True):
            tr = Tracer(enabled=enabled)
            with tr.wall("engine.prefill", who="node1", rows=2) as sp:
                x = sum(range(1000))
            assert x == 499500
            assert sp.dt > 0.0
            if enabled:
                (s,) = tr.spans
                assert s.clock == WALL and s.name == "engine.prefill"
                assert (s.t0, s.t1) == (sp.t0, sp.t1)
                assert s.attrs == {"rows": 2}
            else:
                assert tr.spans == []

    def test_by_request_groups_sorts_and_drops_batch_spans(self):
        tr = Tracer()
        tr.span("engine.decode", "r1", "n0", 2.0, 3.0)
        tr.span("route.decide", "r1", "n0", 0.0, 1.0)
        tr.span("engine.decode_step", "", "n0", 0.0, 0.1)   # batch-scoped
        by = tr.by_request()
        assert list(by) == ["r1"]
        assert [s.name for s in by["r1"]] == ["route.decide", "engine.decode"]

    def test_set_tracer_swaps_and_restores_process_default(self):
        assert not get_tracer().enabled      # process default starts off
        mine = Tracer()
        old = set_tracer(mine)
        try:
            assert get_tracer() is mine
        finally:
            assert set_tracer(old) is mine
        assert get_tracer() is old

    def test_wall_now_is_monotonic(self):
        a = wall_now()
        assert wall_now() >= a


# ---------------------------------------------------------------------------
# 2. metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counter_labels_fan_out_into_series(self):
        reg = MetricsRegistry()
        reg.counter("net.messages", kind="probe").inc()
        reg.counter("net.messages", kind="probe").inc(2)
        reg.counter("net.messages", kind="gossip").inc()
        assert reg.value("net.messages", kind="probe") == 3.0
        assert reg.value("net.messages", kind="gossip") == 1.0
        assert reg.value("net.messages", kind="bounce") == 0.0

    def test_gauge_is_last_write_wins(self):
        reg = MetricsRegistry()
        reg.gauge("queue.depth", node="n0").set(4.0)
        reg.gauge("queue.depth", node="n0").set(2.0)
        assert reg.value("queue.depth", node="n0") == 2.0

    def test_histogram_buckets_and_overflow(self):
        reg = MetricsRegistry()
        h = reg.histogram("latency", buckets=(0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 5.0, 50.0):
            h.observe(v)
        assert h.count == 4 and h.sum == pytest.approx(55.55)
        assert h.counts == [1, 1, 1]         # 50.0 -> implicit +inf bucket
        assert isinstance(h, Histogram)

    def test_snapshot_shape_and_series_keys(self):
        reg = MetricsRegistry()
        reg.counter("net.dropped", reason="offline").inc()
        reg.gauge("g").set(1.5)
        reg.histogram("h", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        assert snap["counters"] == {"net.dropped{reason=offline}": 1.0}
        assert snap["gauges"] == {"g": 1.5}
        assert snap["histograms"]["h"] == {
            "count": 1, "sum": 0.5, "bounds": [1.0], "counts": [1]}
        json.dumps(snap)                     # JSON-able end to end

    def test_type_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x", a=1)
        with pytest.raises(TypeError):
            reg.gauge("x", a=1)

    def test_set_registry_swaps_and_restores(self):
        mine = MetricsRegistry()
        old = set_registry(mine)
        try:
            assert get_registry() is mine
        finally:
            set_registry(old)
        assert get_registry() is old

    def test_queued_drop_feeds_msg_counts_and_registry(self):
        # satellite (DESIGN.md §Observability): a churn-dropped queued
        # request was previously invisible; it must now show up both in
        # the "dropped" key next to msg_counts and as a labeled counter
        from repro.core import DuelParams, Network, Node, NodePolicy
        from repro.core.node import QueuedRequest
        from repro.sim import make_profile
        from repro.sim.workload import Request
        net = Network(mode="decentralized", seed=0,
                      duel=DuelParams(p_d=0.0, k_judges=0))
        for nid in ("n0", "n1"):
            net.add_node(Node(nid, make_profile(quality=0.5),
                              policy=NodePolicy()))
        net.nodes["n0"].online = False
        req = Request(rid="r0", origin="n1", arrival=0.0, prompt_tokens=8,
                      output_tokens=4, slo_s=30.0)
        net.nodes["n0"].enqueue(
            QueuedRequest(req, 0.0, delegated=True, origin_node="n1"))
        assert net.msg_counts["dropped"] == 1
        assert net.registry.value("net.dropped", reason="offline") == 1.0
        # the other routing kinds flow through the same registry
        net._count_msg("probe", 2)
        net._count_giveup("gossip")
        assert net.registry.value("net.messages", kind="probe") == 2.0
        assert net.msg_counts["giveup"] == 1
        assert net.registry.value("net.giveup", path="gossip") == 1.0


# ---------------------------------------------------------------------------
# 3. export
# ---------------------------------------------------------------------------

def _two_domain_tracer():
    tr = Tracer()
    tr.span("route.decide", "r1", "n0", 0.0, 0.1, mode="gossip")
    tr.event("executor.admit", "r1", "n1", 0.1)
    tr.span("engine.decode", "r1", "n1", 0.1, 1.1)
    tr.span("engine.decode_step", "", "node1", 100.0, 100.25, clock=WALL,
            batch=2)
    return tr


class TestChromeExport:
    def test_clock_domains_become_processes(self):
        payload = to_chrome_trace(_two_domain_tracer().spans)
        evs = payload["traceEvents"]
        procs = {e["args"]["name"]: e["pid"] for e in evs
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert procs == {"sim-time": 1, "wall-time": 2}
        assert payload["displayTimeUnit"] == "ms"

    def test_intervals_are_complete_events_instants_are_instants(self):
        evs = to_chrome_trace(_two_domain_tracer().spans)["traceEvents"]
        by_name = {e["name"]: e for e in evs if e["ph"] in ("X", "i")}
        dec = by_name["route.decide"]
        assert dec["ph"] == "X" and dec["dur"] == pytest.approx(1e5)
        assert dec["ts"] == 0.0 and dec["args"]["rid"] == "r1"
        assert by_name["executor.admit"]["ph"] == "i"
        assert by_name["executor.admit"]["s"] == "t"
        # wall timestamps are rebased to the earliest wall span
        step = by_name["engine.decode_step"]
        assert step["pid"] == 2 and step["ts"] == 0.0
        assert step["dur"] == pytest.approx(0.25e6)

    def test_threads_are_named_per_who(self):
        evs = to_chrome_trace(_two_domain_tracer().spans)["traceEvents"]
        threads = {(e["pid"], e["args"]["name"]) for e in evs
                   if e["ph"] == "M" and e["name"] == "thread_name"}
        assert (1, "n0") in threads and (1, "n1") in threads
        assert (2, "node1") in threads

    def test_write_chrome_trace_round_trips(self, tmp_path):
        p = tmp_path / "trace.json"
        payload = write_chrome_trace(_two_domain_tracer().spans, str(p))
        assert json.loads(p.read_text()) == payload


class TestBreakdown:
    def test_latency_breakdown_sums_stages_and_covers_total(self):
        bd = latency_breakdown(_two_domain_tracer().spans)
        assert list(bd) == ["r1"]            # batch-scoped "" excluded
        entry = bd["r1"]
        assert entry["spans"] == 3
        assert entry["stages"]["route.decide"] == pytest.approx(0.1)
        assert entry["stages"]["engine.decode"] == pytest.approx(1.0)
        assert entry["total"] == pytest.approx(1.1)

    def test_breakdown_report_orders_and_limits(self):
        tr = _two_domain_tracer()
        tr.span("engine.decode", "r2", "n0", 0.0, 5.0)
        text = breakdown_report(tr.spans)
        assert text.index("r2:") < text.index("r1:")   # slowest first
        only = breakdown_report(tr.spans, limit=1)
        assert "r2:" in only and "r1:" not in only


# ---------------------------------------------------------------------------
# 4. the latency partition, end to end
# ---------------------------------------------------------------------------

class TestLatencyPartition:
    """The --trace acceptance invariant (DESIGN.md §Observability), on the
    same traced sim the bench harness drives."""

    @pytest.fixture(scope="class")
    def traced_run(self):
        from benchmarks.run import _traced_sim_mix
        return _traced_sim_mix(n_requests=10)

    def test_merged_sim_spans_reconstruct_latency(self, traced_run):
        from benchmarks.run import _span_coverage_errors
        m, tr, _net = traced_run
        assert len(m.completed) == 10
        errs = _span_coverage_errors(m, tr.spans)
        assert errs and max(errs.values()) <= 0.05, errs

    def test_every_request_carries_the_lifecycle_chain(self, traced_run):
        m, tr, _net = traced_run
        by = tr.by_request()
        for c in m.completed:
            names = {s.name for s in by[c.rid]}
            assert {"route.decide", "executor.queue", "executor.admit",
                    "engine.prefill", "engine.decode"} <= names, \
                f"{c.rid}: {sorted(names)}"

    def test_spans_nest_inside_the_request_lifetime(self, traced_run):
        m, tr, _net = traced_run
        by = tr.by_request()
        for c in m.completed:
            for s in by[c.rid]:
                if s.clock == SIM:
                    assert c.arrival - 1e-9 <= s.t0 <= s.t1 <= \
                        c.finish + 1e-9, (c.rid, s.name)

    def test_process_tracer_restored_after_run(self, traced_run):
        _m, tr, _net = traced_run
        assert get_tracer() is not tr


# ---------------------------------------------------------------------------
# 4b. MetricsCollector aggregate regressions (satellites)
# ---------------------------------------------------------------------------

def _cr(rid, executor="n0", arrival=0.0, finish=1.0, slo=2.0, duel=False):
    return CompletedRequest(rid=rid, origin="n0", executor=executor,
                            arrival=arrival, finish=finish, slo_s=slo,
                            delegated=False, is_duel_extra=duel)


class TestMetricsCollectorAggregates:
    def test_per_executor_counts_excludes_duel_extras_by_default(self):
        m = MetricsCollector()
        m.record(_cr("u1", executor="n0"))
        m.record(_cr("u2", executor="n1"))
        m.record(_cr("d1", executor="n0", duel=True))   # duel challenger
        m.record(_cr("d2", executor="n0", duel=True))   # duel judge
        # the regression: duel extras used to inflate duel-heavy nodes
        assert m.per_executor_counts() == {"n0": 1, "n1": 1}
        # raw count stays available for duel accounting
        assert m.per_executor_counts(user_only=False) == {"n0": 3, "n1": 1}

    def test_windowed_latency_empty_collector(self):
        assert MetricsCollector().windowed_latency(1.0, 10.0) == []

    def test_windowed_latency_skips_empty_windows(self):
        m = MetricsCollector()
        m.record(_cr("a", finish=0.5))
        m.record(_cr("b", finish=8.5, arrival=8.0))
        out = m.windowed_latency(1.0, 10.0)
        assert [t for t, _ in out] == [0.5, 8.5]       # midpoints only
        assert out[0][1] == pytest.approx(0.5)
        assert out[1][1] == pytest.approx(0.5)

    def test_windowed_latency_window_larger_than_t_end(self):
        m = MetricsCollector()
        m.record(_cr("a", finish=3.0))
        out = m.windowed_latency(10.0, 4.0)
        # one window [0, 10) starting inside [0, t_end) catches the finish
        assert len(out) == 1 and out[0][1] == pytest.approx(3.0)

    def test_latency_cdf_single_request(self):
        m = MetricsCollector()
        m.record(_cr("a", finish=2.5))
        assert m.latency_cdf(n=1) == [(2.5, 0.0)]
        cdf = m.latency_cdf()
        assert cdf[0] == (2.5, 0.0) and cdf[-1] == (2.5, 1.0)
        assert MetricsCollector().latency_cdf() == []

    def test_slo_curve_is_monotonic_in_scale(self):
        m = MetricsCollector()
        for i, lat in enumerate((0.5, 1.0, 1.5, 3.0, 6.0)):
            m.record(_cr(f"r{i}", finish=lat, slo=2.0))
        scales = (0.25, 0.5, 1.0, 2.0, 4.0)
        curve = m.slo_curve(scales)
        assert [s for s, _ in curve] == list(scales)
        atts = [a for _, a in curve]
        assert all(b >= a for a, b in zip(atts, atts[1:]))
        assert atts[-1] == 1.0
