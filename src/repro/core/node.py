"""A WWW.Serve node (paper Figure 2).

Each node bundles the five managers:

* **Request Manager** — local + delegated queues, admission timestamps.
* **Policy Manager**  — ``NodePolicy`` decisions (offload / accept / priority).
* **Ledger Manager**  — either a shared ledger handle or a local CreditChain.
* **Model Manager**   — backend-agnostic execution: an analytic
  ``BackendProfile`` (simulation) or a real JAX serving engine callback.
* **Communication Manager** — message send via the network bus (latency
  injected by the event loop; ZeroMQ ROUTER in the paper).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, TYPE_CHECKING

import numpy as np

from repro.core.gossip import PeerView
from repro.core.policy import NodePolicy
from repro.sim.servicemodel import BackendProfile
from repro.sim.workload import Request

if TYPE_CHECKING:
    from repro.core.network import Network


@dataclass
class QueuedRequest:
    req: Request
    enqueue_time: float
    delegated: bool
    origin_node: str              # who the response must be returned to
    duel_id: Optional[str] = None # set if this execution is part of a duel


class Node:
    def __init__(self, node_id: str, profile: BackendProfile,
                 policy: Optional[NodePolicy] = None,
                 quality: Optional[float] = None) -> None:
        self.id = node_id
        self.profile = profile
        self.policy = policy or NodePolicy()
        self.quality = profile.quality if quality is None else quality
        self.secret = node_id.encode() + b"-secret"
        self.view = PeerView(node_id, addr=f"tcp://{node_id}:5555")
        self.online = True

        # Request Manager state
        self.local_queue: List[QueuedRequest] = []
        self.delegated_queue: List[QueuedRequest] = []
        self.n_active = 0

        # stats
        self.served_total = 0
        self.served_delegated = 0
        self.duel_wins = 0
        self.duel_losses = 0

        self.network: Optional["Network"] = None  # set on Network.add_node

    # ------------------------------------------------------------------ utils
    @property
    def queue_len(self) -> int:
        return len(self.local_queue) + len(self.delegated_queue)

    def utilization(self) -> float:
        return self.n_active / max(1, self.profile.saturation)

    def balance(self) -> float:
        return self.network.ledger_balance(self.id)

    # --------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        """User submits a request to this node (paper Fig 9, Step 1)."""
        assert self.network is not None
        if not self.online:
            # user traffic to an offline node is re-targeted by the network
            self.network.resubmit_elsewhere(req)
            return
        net, rng = self.network, self.network.rng
        # Step 2: local vs offload decision (Policy Manager)
        if (net.mode == "decentralized"
                and self.policy.wants_offload(self.queue_len, self.n_active,
                                              self.profile.saturation,
                                              self.balance(), rng)):
            if net.try_offload(self, req):
                return
        self.enqueue(QueuedRequest(req, net.loop.now, delegated=False,
                                   origin_node=self.id))

    def enqueue(self, qr: QueuedRequest) -> None:
        (self.delegated_queue if qr.delegated else self.local_queue).append(qr)
        self._maybe_start()

    def _pop_next(self) -> Optional[QueuedRequest]:
        if self.policy.prioritize_local:
            for q in (self.local_queue, self.delegated_queue):
                if q:
                    return q.pop(0)
            return None
        both = self.local_queue + self.delegated_queue
        if not both:
            return None
        qr = min(both, key=lambda x: x.enqueue_time)
        (self.local_queue if not qr.delegated else self.delegated_queue).remove(qr)
        return qr

    def _maybe_start(self) -> None:
        net = self.network
        while (self.online and self.n_active < self.profile.max_concurrency
               and self.queue_len > 0):
            qr = self._pop_next()
            if qr is None:
                break
            self.n_active += 1
            st = self.profile.service_time(qr.req.prompt_tokens,
                                           qr.req.output_tokens,
                                           self.n_active)
            net.loop.schedule(st, lambda qr=qr: self._finish(qr))

    def _finish(self, qr: QueuedRequest) -> None:
        self.n_active -= 1
        self.served_total += 1
        if qr.delegated:
            self.served_delegated += 1
        self.network.on_request_finished(self, qr)
        self._maybe_start()

    # ------------------------------------------------------------------ churn
    def go_offline(self) -> None:
        self.online = False
        self.view.set_offline(self.network.loop.now)

    def go_online(self) -> None:
        self.online = True
        self.view.heartbeat(self.network.loop.now)
        self.network.resync_chain(self.id)   # catch up on missed blocks
        self._maybe_start()
