"""Fig 5: dynamic participation — nodes joining (a) and leaving (b)."""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.settings import OUTPUT_MEAN, SLO_S, build_network
from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import WorkloadSpec, make_profile, make_requests, uniform_phases


def _mk_net(seed=0) -> Network:
    return Network(mode="decentralized", seed=seed, ledger_mode="shared",
                   duel=DuelParams(p_d=0.05), init_balance=1000.0)


def run_join(seed: int = 0, t_end: float = 600.0) -> Dict:
    """Start with 2 nodes under pressure; nodes 3..6 join at 150/250/350/450s."""
    net = _mk_net(seed)
    join_times = {"node3": 150.0, "node4": 250.0, "node5": 350.0,
                  "node6": 450.0}
    for i in range(1, 7):
        nid = f"node{i}"
        node = Node(nid, make_profile("qwen3-8b", "ADA6000", "sglang",
                                      quality=0.7))
        net.add_node(node)
        if nid in join_times:
            node.online = False
            node.view.set_offline(0.0)
            net.loop.schedule(join_times[nid],
                              lambda n=node: n.go_online())
    specs = [WorkloadSpec(f"node{i}", uniform_phases(t_end, 5.0),
                          output_mean=OUTPUT_MEAN, slo_s=SLO_S)
             for i in (1, 2)]
    m = net.run(make_requests(specs, seed=7 + seed), until=t_end)
    trace = m.windowed_latency(window=50.0, t_end=t_end + 200)
    return {"events": sorted(join_times.values()), "trace": trace,
            "slo": m.slo_attainment(), "n": len(m.completed)}


def run_leave(seed: int = 0, t_end: float = 600.0) -> Dict:
    """Start with 4 nodes; two leave at 200s and 400s."""
    net = _mk_net(seed)
    nodes = []
    for i in range(1, 5):
        node = Node(f"node{i}", make_profile("qwen3-8b", "ADA6000", "sglang",
                                             quality=0.7))
        net.add_node(node)
        nodes.append(node)
    net.loop.schedule(200.0, lambda: nodes[2].go_offline())
    net.loop.schedule(400.0, lambda: nodes[3].go_offline())
    specs = [WorkloadSpec(f"node{i}", uniform_phases(t_end, 8.0),
                          output_mean=OUTPUT_MEAN, slo_s=SLO_S)
             for i in (1, 2)]
    m = net.run(make_requests(specs, seed=9 + seed), until=t_end)
    trace = m.windowed_latency(window=50.0, t_end=t_end + 200)
    return {"events": [200.0, 400.0], "trace": trace,
            "slo": m.slo_attainment(), "n": len(m.completed)}


def main(rows: List[str]) -> None:
    t0 = time.perf_counter()
    j = run_join()
    l = run_leave()
    us = (time.perf_counter() - t0) * 1e6

    def seg_mean(trace, lo, hi):
        xs = [v for t, v in trace if lo <= t < hi]
        return float(np.mean(xs)) if xs else float("nan")

    # joins: pre-join overload peak vs post-join steady state
    j_before = seg_mean(j["trace"], 150, 300)
    j_after = seg_mean(j["trace"], 450, 600)
    # leaves: before first leave vs after second
    l_before = seg_mean(l["trace"], 50, 200)
    l_after = seg_mean(l["trace"], 400, 600)
    rows.append(f"fig5a_join,{us:.0f},lat_before={j_before:.1f};"
                f"lat_after={j_after:.1f};drops={j_before > j_after}")
    rows.append(f"fig5b_leave,{us:.0f},lat_before={l_before:.1f};"
                f"lat_after={l_after:.1f};rises={l_after > l_before}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
