"""Scale-out routing invariants (DESIGN.md §6.2-gossip; ROADMAP item 1).

Tier-1 runs a small-pool gossip-vs-probe comparison; the 10k-node point
with partial views is a deep sweep behind ``-m slow`` (the 100/1k points
are exercised — with hard message-cut and SLO bars — by ``--bench`` and
the checked-in ``BENCH_scheduling.json`` via ``tests/test_compat.py``).
"""

import pytest

from benchmarks.scaling import SCALE_POINTS, build_scale_network, \
    run_scale_point


class TestSmallPoolParity:
    _POINT = dict(hot=4, hot_ia=1.0, bg_ia=16.0, t_end=20.0,
                  gossip_interval=1.0, view_cap=None)

    def test_gossip_cuts_messages_at_matched_slo(self):
        g = run_scale_point(40, "gossip", point=self._POINT)
        p = run_scale_point(40, "probe", point=self._POINT)
        # both routing flavors complete the whole workload ...
        assert g["n"] == g["n_submitted"]
        assert p["n"] == p["n_submitted"]
        # ... at comparable SLO attainment, but the digest plane routes
        # with strictly fewer messages per request
        assert abs(g["slo_attainment"] - p["slo_attainment"]) <= 0.05
        assert g["routing_msgs_per_req"] < p["routing_msgs_per_req"]

    def test_gossip_spends_probes_only_on_contention(self):
        g = run_scale_point(40, "gossip", point=self._POINT)
        # blind dispatches must dominate live probes: the stale-digest
        # table resolves most routing decisions without a round-trip
        assert g["dispatches"] > 0
        assert g["probes"] <= g["dispatches"]


class TestScalePoints:
    def test_scale_points_cover_required_sizes(self):
        assert set(SCALE_POINTS) == {100, 1000, 10000}
        # the 10k point must bound per-node view size (partial views)
        assert SCALE_POINTS[10000]["view_cap"] is not None

    def test_build_network_wires_routing_and_view_cap(self):
        net, specs = build_scale_network(100, "gossip", seed=1)
        assert net.routing == "gossip" and not net.power_of_two
        assert len(net.nodes) == 100 and len(specs) == 100
        netp, _ = build_scale_network(100, "probe", seed=1)
        # the probe baseline runs at its strongest configuration
        assert netp.routing == "probe" and netp.power_of_two


@pytest.mark.slow
class TestTenThousandNodes:
    def test_10k_gossip_point_with_partial_views(self):
        r = run_scale_point(10000, "gossip")
        assert r["n"] > 0
        assert r["slo_attainment"] >= 0.95
        # partial views keep per-request routing cost size-independent
        assert r["routing_msgs_per_req"] < 1.0
