"""Pure-jnp oracles for the Pallas kernels (also the CPU/dry-run path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (decode_attention as decode_ref,
                                    flash_attention as flash_ref,
                                    kv_dequantize, reference_attention,
                                    verify_attention as verify_ref)


def _gather_pages(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(P, page, ...) pool + (B, maxp) table -> (B, maxp*page, ...) view."""
    b, maxp = block_tables.shape
    page = pool.shape[1]
    return pool[block_tables].reshape((b, maxp * page) + pool.shape[2:])


def paged_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Oracle for block-table paged decode attention.

    q: (B, 1, H, D); k_pool/v_pool: (P, page, Hkv, D) — a shared pool of
    fixed-size KV pages; block_tables: (B, maxp) int32 mapping each row's
    logical page index to a physical page (entries past a row's allocation
    may point anywhere — typically the scratch page 0 — and are masked out
    by ``lengths``); lengths: (B,) int32 valid-token counts per row.

    Gathers each row's pages into a contiguous (B, maxp*page, Hkv, D) view
    and defers to the dense per-row-length decode oracle.  Returns
    (B, 1, H, D).
    """
    k = _gather_pages(k_pool, block_tables)
    v = _gather_pages(v_pool, block_tables)
    return decode_ref(q, k, v, lengths)


def paged_decode_quant_ref(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, k_scale_pool: jax.Array,
                           v_scale_pool: jax.Array,
                           block_tables: jax.Array,
                           lengths: jax.Array) -> jax.Array:
    """Oracle for int8 paged decode attention (DESIGN.md §6.1-paged).

    Pools are int8 with per-token-per-head scale pools (P, page, Hkv, 1)
    riding the same block-table indirection.  Gathers pages and scales,
    dequantizes via the shared ``models.attention.kv_dequantize``, and
    defers to the fp oracle.  Returns (B, 1, H, D).
    """
    k = kv_dequantize(_gather_pages(k_pool, block_tables),
                      _gather_pages(k_scale_pool, block_tables), q.dtype)
    v = kv_dequantize(_gather_pages(v_pool, block_tables),
                      _gather_pages(v_scale_pool, block_tables), q.dtype)
    return decode_ref(q, k, v, lengths)


def paged_verify_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_tables: jax.Array, lengths: jax.Array) -> jax.Array:
    """Oracle for block-table multi-token verify attention (speculative
    decoding, DESIGN.md §6.1-spec).

    q: (B, K, H, D) — K new tokens per row whose KV has already been
    scattered into the pool at positions ``lengths[b] .. lengths[b]+K-1``;
    pools/block_tables as in :func:`paged_decode_ref`; lengths: (B,) int32
    valid tokens per row BEFORE the K new tokens.  Query j attends
    positions ``<= lengths[b] + j`` (causal among the new tokens).

    Gathers each row's pages into a contiguous view and defers to the
    dense multi-token verify oracle.  Returns (B, K, H, D).
    """
    k = _gather_pages(k_pool, block_tables)
    v = _gather_pages(v_pool, block_tables)
    return verify_ref(q, k, v, lengths)


def paged_verify_quant_ref(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, k_scale_pool: jax.Array,
                           v_scale_pool: jax.Array,
                           block_tables: jax.Array,
                           lengths: jax.Array) -> jax.Array:
    """Oracle for int8 multi-token verify attention: gather + dequantize
    (shared ``models.attention.kv_dequantize``), then the fp verify
    oracle.  Returns (B, K, H, D).
    """
    k = kv_dequantize(_gather_pages(k_pool, block_tables),
                      _gather_pages(k_scale_pool, block_tables), q.dtype)
    v = kv_dequantize(_gather_pages(v_pool, block_tables),
                      _gather_pages(v_scale_pool, block_tables), q.dtype)
    return verify_ref(q, k, v, lengths)


__all__ = ["decode_ref", "flash_ref", "reference_attention",
           "paged_decode_ref", "paged_decode_quant_ref",
           "paged_verify_ref", "paged_verify_quant_ref", "verify_ref"]
