"""§Perf variant correctness: every beyond-paper optimization is value-exact."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import runtime
from repro.models.attention import (decode_attention,
                                    decode_attention_seqsharded,
                                    flash_attention, reference_attention)


class TestGQANativeFlash:
    @pytest.mark.parametrize("hkv,rep,win,causal", [
        (2, 4, None, True), (1, 8, 32, True), (3, 3, None, False),
        (4, 1, None, True),
    ])
    def test_matches_reference(self, hkv, rep, win, causal):
        h = hkv * rep
        ks = jax.random.split(jax.random.PRNGKey(h), 3)
        q = jax.random.normal(ks[0], (2, 96, h, 32))
        k = jax.random.normal(ks[1], (2, 96, hkv, 32))
        v = jax.random.normal(ks[2], (2, 96, hkv, 32))
        ref = reference_attention(q, k, v, causal=causal, window=win)
        with runtime.perf_flags(gqa_native_=True):
            out = flash_attention(q, k, v, causal=causal, window=win,
                                  q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=1e-3)


class TestSeqShardedDecode:
    def test_no_mesh_fallback_exact(self):
        """Without a mesh the shard_map path falls back to the reference —
        including the ring write."""
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        B, S, H, Hkv, D = 3, 48, 4, 2, 16
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kc = jax.random.normal(ks[1], (B, S, Hkv, D))
        vc = jax.random.normal(ks[2], (B, S, Hkv, D))
        kn = jax.random.normal(ks[3], (B, 1, Hkv, D))
        vn = jax.random.normal(ks[4], (B, 1, Hkv, D))
        slot = jnp.asarray(20, jnp.int32)
        n_valid = jnp.asarray(21, jnp.int32)
        kc_r = jax.lax.dynamic_update_slice(kc, kn, (0, 20, 0, 0))
        vc_r = jax.lax.dynamic_update_slice(vc, vn, (0, 20, 0, 0))
        ref = decode_attention(q, kc_r, vc_r, n_valid)
        out, kc2, vc2 = decode_attention_seqsharded(q, kc, vc, kn, vn, slot,
                                                    n_valid)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)
        np.testing.assert_array_equal(np.asarray(kc2), np.asarray(kc_r))


class TestInt8KV:
    def test_quant_roundtrip_error_bounded(self):
        from repro.models.attention import kv_dequantize, kv_quantize
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 4, 64)) * 3.0
        q8, s = kv_quantize(x)
        assert q8.dtype == jnp.int8 and s.shape == (2, 32, 4, 1)
        back = kv_dequantize(q8, s, jnp.float32)
        rel = np.abs(np.asarray(back - x)) / (np.abs(np.asarray(x)).max())
        assert rel.max() < 0.02          # int8 symmetric: <~1/127 per scale

    def test_decode_matches_bf16_path(self):
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        cfgq = cfg.replace(kv_quant=True)
        fam = registry.get_family(cfg)
        params = registry.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                                  cfg.vocab_size)
        lg, c = fam.prefill(params, cfg, {"tokens": toks}, q_chunk=32,
                            kv_chunk=32, capacity=64)
        lgq, cq = fam.prefill(params, cfgq, {"tokens": toks}, q_chunk=32,
                              kv_chunk=32, capacity=64)
        assert cq["k"].dtype == jnp.int8
        nt = jnp.argmax(lg, -1).astype(jnp.int32)
        for _ in range(4):
            o1, c = fam.decode_step(params, cfg, c, nt)
            o2, cq = fam.decode_step(params, cfgq, cq, nt)
            assert bool((jnp.argmax(o1, -1) == jnp.argmax(o2, -1)).all())
            nt = jnp.argmax(o1, -1).astype(jnp.int32)

    def test_seqsharded_quant_fallback_consistent(self):
        """kv_quant + decode_seq_shard (no mesh -> fallback) == plain quant."""
        from repro.configs import get_config
        from repro.models import registry
        cfgq = get_config("qwen3-8b").smoke().replace(dtype="float32",
                                                      kv_quant=True)
        fam = registry.get_family(cfgq)
        params = registry.init(jax.random.PRNGKey(0), cfgq)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  cfgq.vocab_size)
        lg, cache = fam.prefill(params, cfgq, {"tokens": toks}, q_chunk=32,
                                kv_chunk=32, capacity=48)
        nt = jnp.argmax(lg, -1).astype(jnp.int32)
        base, _ = fam.decode_step(params, cfgq, cache, nt)
        with runtime.perf_flags(decode_seq_shard_=True):
            alt, _ = fam.decode_step(params, cfgq, cache, nt)
        np.testing.assert_allclose(np.asarray(base), np.asarray(alt),
                                   atol=2e-4)


class TestFlagHygiene:
    def test_flags_reset_after_context(self):
        assert not runtime.seq_parallel()
        with runtime.perf_flags(seq_parallel_=True, gqa_native_=True):
            assert runtime.seq_parallel() and runtime.gqa_native()
        assert not runtime.seq_parallel() and not runtime.gqa_native()

    def test_decode_step_value_invariant_under_flags(self):
        """A full decode step gives identical logits with/without the §Perf
        flags on a single device (flags change schedules, never math)."""
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        fam = registry.get_family(cfg)
        params = registry.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  cfg.vocab_size)
        lg, cache = fam.prefill(params, cfg, {"tokens": toks},
                                q_chunk=32, kv_chunk=32, capacity=48)
        nt = jnp.argmax(lg, -1).astype(jnp.int32)
        base, _ = fam.decode_step(params, cfg, cache, nt)
        with runtime.perf_flags(decode_seq_shard_=True, gqa_native_=True):
            alt, _ = fam.decode_step(params, cfg, cache, nt)
        np.testing.assert_allclose(np.asarray(base), np.asarray(alt),
                                   atol=2e-4)


class TestMoEA2A:
    def test_single_device_fallback(self):
        """Without a multi-way model axis, the a2a path falls back."""
        from repro.models import moe
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=32, vocab_size=512,
                          head_dim=16, n_experts=4, top_k=2,
                          capacity_factor=8.0, dtype="float32")
        params = moe.init(jax.random.PRNGKey(0), cfg)
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        ref, _ = moe.moe_mlp(cfg, lp, x)
        with runtime.perf_flags(moe_a2a_=True):
            out, _ = moe.moe_mlp(cfg, lp, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)
