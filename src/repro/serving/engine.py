"""A small batched serving engine — the node's Model Manager backend.

Real (not simulated) JAX inference: requests queue up, the engine prefills a
batch together (padded to a bucket), then decodes all active sequences in
lock-step until each hits EOS or its token budget.  This is the backend used
by the runnable examples and the end-to-end decentralized serving driver
(``repro.launch.serve``); the large-scale scheduling benchmarks use the
analytic service model instead (see DESIGN.md §6.1).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig
from repro.serving.sampling import sample


@dataclass
class GenRequest:
    rid: str
    tokens: np.ndarray            # (S,) prompt token ids
    max_new: int = 32
    temperature: float = 0.0
    result: Optional[np.ndarray] = None
    # engine metrics
    enqueued_at: float = 0.0
    finished_at: float = 0.0


@dataclass
class EngineStats:
    served: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    batches: int = 0


class Engine:
    """Batched prefill + lock-step decode with a jitted step per bucket."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 bucket: int = 64, seed: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        fam = registry.get_family(cfg)
        self._prefill = jax.jit(
            lambda p, b, cap: fam.prefill(p, cfg, b, q_chunk=256,
                                          kv_chunk=256, capacity=cap),
            static_argnums=(2,))
        self._decode = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))
        self.eos_id = 1

    def _pad_bucket(self, n: int) -> int:
        b = self.bucket
        return max(b, (n + b - 1) // b * b)

    def generate_batch(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Serve up to max_batch requests together; returns them completed."""
        assert len(reqs) <= self.max_batch
        t0 = time.perf_counter()
        max_prompt = max(len(r.tokens) for r in reqs)
        plen = self._pad_bucket(max_prompt)
        max_new = max(r.max_new for r in reqs)
        toks = np.full((len(reqs), plen), self.eos_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.tokens):] = r.tokens     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cap = plen + self._pad_bucket(max_new)
        logits, cache = self._prefill(self.params, batch, cap)
        self.stats.prefill_tokens += plen * len(reqs)

        out = np.zeros((len(reqs), max_new), np.int32)
        done = np.zeros(len(reqs), bool)
        temps_np = np.array([r.temperature for r in reqs], np.float32)
        # all-greedy batches (the default) keep the scalar fast path in
        # sample(), skipping the per-step Gumbel draw over the vocab
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        budgets = np.array([r.max_new for r in reqs])
        cur = None
        for step in range(max_new):
            self.key, sk = jax.random.split(self.key)
            cur = sample(sk, logits, temperature=temps,
                         vocab_size=self.cfg.vocab_size)
            out[:, step] = np.asarray(cur[:, 0])
            done |= out[:, step] == self.eos_id
            done |= step + 1 >= budgets
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, cur)
            self.stats.decode_tokens += int((~done).sum())
        for i, r in enumerate(reqs):
            row = out[i, : r.max_new]
            end = np.argmax(row == self.eos_id) if (row ==
                                                    self.eos_id).any() \
                else r.max_new
            r.result = row[: max(int(end), 1)]
            r.finished_at = time.perf_counter()
        self.stats.served += len(reqs)
        self.stats.batches += 1
        return reqs

    def serve(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """FIFO continuous batching: group the queue into max_batch waves."""
        out: List[GenRequest] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self.generate_batch(reqs[i: i + self.max_batch]))
        return out

    def logprob_of(self, tokens: np.ndarray) -> float:
        """Sequence log-likelihood under this engine's model — used by the
        real-engine duel judges (DESIGN.md §6.2)."""
        t = jnp.asarray(tokens[None, :])
        logits = registry.apply_logits(self.params, self.cfg,
                                       {"tokens": t[:, :-1]},
                                       q_chunk=256, kv_chunk=256)
        logits = logits.astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(lp, t[:, 1:, None], axis=-1)
        return float(jnp.sum(gold))
