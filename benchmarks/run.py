"""Benchmark harness: one entry per paper table/figure + substrate benches.

Prints ``name,us_per_call,derived`` CSV (one row per artifact).  Roofline
numbers come from ``repro.launch.dryrun`` (see EXPERIMENTS.md §Roofline) —
that path needs 512 host devices and therefore runs as its own process.
"""

from __future__ import annotations

import sys
import time
from typing import List


def main() -> None:
    rows: List[str] = ["name,us_per_call,derived"]
    from benchmarks import (duel_overhead, dynamic, gametheory, kernels,
                            policies, protocol, quality, scheduling)
    for mod, label in ((scheduling, "scheduling (Fig4/Tab2)"),
                       (dynamic, "dynamic participation (Fig5)"),
                       (quality, "quality incentivization (Fig6)"),
                       (duel_overhead, "duel overhead (Fig7)"),
                       (policies, "user-level policies (Fig8)"),
                       (gametheory, "game theory (Sec5)"),
                       (protocol, "protocol: ledger ablation + gossip (AppA2/C)"),
                       (kernels, "pallas kernels")):
        t0 = time.perf_counter()
        mod.main(rows)
        dt = time.perf_counter() - t0
        print(f"# {label}: {dt:.1f}s", file=sys.stderr, flush=True)
    print("\n".join(rows))


if __name__ == "__main__":
    main()
