"""check_docs: documentation cross-references stay resolvable in tier-1.

Code and the planning docs cite DESIGN.md sections by anchor (``§6.1``,
``§6.1-disagg``, ...).  Renaming or deleting a section must fail loudly
here instead of leaving dangling references in ROADMAP.md / CHANGES.md /
README.md — the executor layer is meant to be learnable from the docs
without reading PR history.
"""

import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parents[1]

# a §-anchor: "§6.1", "§6.1-paged", "§Arch-applicability" — trailing
# punctuation (".", ")", ":") is prose, not part of the anchor
ANCHOR = re.compile(r"§[A-Za-z0-9](?:[A-Za-z0-9.\-]*[A-Za-z0-9])?")

# markdown files that cite DESIGN.md anchors
REFERRERS = ("ROADMAP.md", "CHANGES.md", "README.md")


def _defined_anchors():
    """Anchors DESIGN.md defines: one per §-carrying heading line."""
    out = set()
    for line in (REPO / "DESIGN.md").read_text().splitlines():
        if line.lstrip().startswith("#"):
            out.update(ANCHOR.findall(line))
    return out


class TestCheckDocs:
    def test_design_defines_the_cited_sections(self):
        anchors = _defined_anchors()
        for a in ("§6.1", "§6.1-paged", "§6.1-disagg", "§6.1-spec", "§6.2",
                  "§6.3", "§Arch-applicability"):
            assert a in anchors, f"DESIGN.md lost its {a} heading"

    def test_no_dangling_anchor_references(self):
        defined = _defined_anchors()
        dangling = []
        for name in REFERRERS:
            path = REPO / name
            assert path.exists(), f"{name} missing"
            for i, line in enumerate(path.read_text().splitlines(), 1):
                for ref in ANCHOR.findall(line):
                    if ref not in defined:
                        dangling.append(f"{name}:{i}: {ref}")
        assert not dangling, (
            "dangling DESIGN.md anchor references (rename the section back "
            "or update the referrer):\n  " + "\n  ".join(dangling))

    def test_anchor_regex_strips_trailing_punctuation(self):
        assert ANCHOR.findall("see §6.1-paged): and §6.2, then §6.1.") == \
            ["§6.1-paged", "§6.2", "§6.1"]


class TestReadme:
    """Acceptance: the root README exists and teaches the entry points."""

    def test_readme_covers_the_entry_points(self):
        text = (REPO / "README.md").read_text()
        for needle in ("python -m pytest", "--smoke", "--bench",
                       "pytest -m slow", "DESIGN.md"):
            assert needle in text, f"README.md does not mention {needle!r}"

    def test_readme_maps_the_architecture(self):
        text = (REPO / "README.md").read_text()
        for pkg in ("repro/core", "repro/sim", "repro/serving",
                    "repro/kernels", "repro/compat"):
            assert pkg in text, f"README.md architecture map misses {pkg}"
