"""Quickstart: a 4-node WWW.Serve network in ~30 lines.

Builds the decentralized network, submits a bursty workload to one hot node,
and shows the protocol redistributing it — vs single-node and centralized
baselines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import (WorkloadSpec, make_profile, make_requests, two_phase,
                       uniform_phases)

T_END = 750.0


def build(mode: str) -> Network:
    net = Network(mode=mode, seed=0, duel=DuelParams(p_d=0.1, k_judges=2),
                  init_balance=100.0)
    for i, gpu in enumerate(("A100", "ADA6000", "RTX4090", "RTX3090")):
        net.add_node(Node(f"node{i+1}",
                          make_profile("qwen3-8b", gpu, "sglang",
                                       quality=0.5 + 0.1 * i),
                          policy=NodePolicy(offload_util_threshold=0.8)))
    return net


def main() -> None:
    specs = [
        WorkloadSpec("node1", two_phase(300, T_END, 3, 20),
                     output_mean=5120, slo_s=360),
        WorkloadSpec("node2", uniform_phases(T_END, 20),
                     output_mean=5120, slo_s=360),
        WorkloadSpec("node3", uniform_phases(T_END, 20),
                     output_mean=5120, slo_s=360),
        WorkloadSpec("node4", two_phase(450, T_END, 20, 3),
                     output_mean=5120, slo_s=360),
    ]
    reqs = make_requests(specs, seed=42)
    print(f"{len(reqs)} user requests over {T_END:.0f}s\n")
    for mode in ("single", "centralized", "decentralized"):
        m = build(mode).run(reqs, until=T_END)
        print(f"{mode:14s} SLO={m.slo_attainment():.3f} "
              f"avg latency={m.avg_latency():7.1f}s "
              f"delegated={m.delegation_rate():.0%}")
    print("\ndecentralized ≈ centralized efficiency, zero coordinators — "
          "that's the paper's headline claim.")


if __name__ == "__main__":
    main()
