"""``python -m repro.analysis`` — run the invariant linter from the shell.

Exit status 0 when no *new* findings (inline-suppressed and baselined
ones are reported but do not fail); 1 otherwise.

    python -m repro.analysis                      # human-readable
    python -m repro.analysis --json               # machine-readable
    python -m repro.analysis --rules layering,twin-drift
    python -m repro.analysis --write-baseline     # grandfather current new
    python -m repro.analysis --no-baseline        # strict: ignore baseline
    python -m repro.analysis --list-rules
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis.framework import (BASELINE_FILE, all_checkers,
                                      run_analysis, save_baseline)


def _default_root() -> pathlib.Path:
    # src/repro/analysis/__main__.py -> repo root is four levels up
    return pathlib.Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter (DESIGN.md §7)")
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="repository root (default: this checkout)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated top-level rule ids to run")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of text")
    ap.add_argument("--baseline", type=pathlib.Path, default=None,
                    help=f"baseline file (default: <root>/{BASELINE_FILE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline; every finding is new")
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather the current new findings into the "
                         "baseline file and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered checkers and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for c in all_checkers():
            print(f"{c.rule_id:16s} {c.description}")
        return 0

    root = (args.root or _default_root()).resolve()
    baseline_path = "" if args.no_baseline else args.baseline
    report = run_analysis(root, rules=args.rules.split(",")
                          if args.rules else None,
                          baseline_path=baseline_path)

    if args.write_baseline:
        path = args.baseline or root / BASELINE_FILE
        save_baseline(path, report.new + report.baselined)
        print(f"wrote {len(report.new) + len(report.baselined)} entries "
              f"to {path}")
        return 0

    if args.as_json:
        payload = {
            "root": str(root),
            "rules": report.rules,
            "wall_s": round(report.wall_s, 3),
            "counts": {"new": len(report.new),
                       "suppressed": len(report.suppressed),
                       "baselined": len(report.baselined)},
            "new": [f.__dict__ for f in report.new],
            "suppressed": [f.__dict__ for f in report.suppressed],
            "baselined": [f.__dict__ for f in report.baselined],
        }
        print(json.dumps(payload, indent=2))
    else:
        for f in report.new:
            print(f.format())
        for f in report.suppressed:
            print(f"{f.format()}  [suppressed]")
        for f in report.baselined:
            print(f"{f.format()}  [baselined]")
        print(f"{len(report.rules)} checkers, "
              f"{len(report.new)} new / {len(report.suppressed)} "
              f"suppressed / {len(report.baselined)} baselined findings "
              f"in {report.wall_s:.2f}s")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
