from repro.models.config import ModelConfig
from repro.models.registry import (FAMILIES, apply_logits, apply_with_aux,
                                   get_family, init, params_shape)

__all__ = ["ModelConfig", "FAMILIES", "apply_logits", "apply_with_aux",
           "get_family", "init", "params_shape"]
