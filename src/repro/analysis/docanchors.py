"""docs-anchors: DESIGN.md §-anchors cited anywhere must resolve.

Code and the planning docs cite DESIGN.md sections by anchor (``§6.1``,
``§6.1-disagg``, ...).  Renaming or deleting a section must fail loudly
instead of leaving dangling references — the executor layer is meant to
be learnable from the docs without reading PR history.  Three sub-rules:

* ``docs-anchors/required`` — DESIGN.md keeps the pinned section set the
  rest of the repo is written against.
* ``docs-anchors/markdown`` — every §-anchor in the referrer markdown
  files (ROADMAP.md, CHANGES.md, README.md, and DESIGN.md's own body)
  resolves to a DESIGN.md heading.
* ``docs-anchors/python`` — a §-anchor in Python source is checked when
  it is *attributed to DESIGN.md*: the text ``DESIGN.md`` appears within
  ~80 characters before the anchor, looking across the previous line so
  wrapped docstrings like ``(DESIGN.md\\n§6.1-spec)`` still count.
  Anchors citing the paper or EXPERIMENTS (``§5``, ``§A.2``, ``§Perf``)
  carry no DESIGN.md attribution and are ignored.
"""

from __future__ import annotations

import re
from typing import Iterable, Set

from repro.analysis.framework import Checker, Finding, RepoIndex, register

# a §-anchor: "§6.1", "§6.1-paged", "§Arch-applicability" — trailing
# punctuation (".", ")", ":") is prose, not part of the anchor
ANCHOR = re.compile(r"§[A-Za-z0-9](?:[A-Za-z0-9.\-]*[A-Za-z0-9])?")

DESIGN = "DESIGN.md"

# markdown files whose §-anchors all refer to DESIGN.md sections
MARKDOWN_REFERRERS = ("ROADMAP.md", "CHANGES.md", "README.md", DESIGN)

# the section set the rest of the repo is written against
REQUIRED_ANCHORS = ("§6.1", "§6.1-paged", "§6.1-disagg", "§6.1-prefix",
                    "§6.1-spec", "§Perf-kernels",
                    "§6.2", "§6.2-gossip", "§6.3", "§7",
                    "§Arch-applicability", "§Observability")

# how far back attribution text may sit from the anchor it qualifies
_ATTRIBUTION_WINDOW = 80


@register
class DocAnchorsChecker(Checker):
    rule_id = "docs-anchors"
    description = ("DESIGN.md §-anchors cited from markdown or "
                   "DESIGN.md-attributed Python docstrings resolve to a "
                   "real heading")

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        if not repo.exists(DESIGN):
            yield Finding("docs-anchors/required", DESIGN, 0,
                          "DESIGN.md is missing")
            return
        defined = self._defined(repo)

        for a in REQUIRED_ANCHORS:
            if a not in defined:
                yield Finding(
                    "docs-anchors/required", DESIGN, 0,
                    f"DESIGN.md lost its {a} heading (rename it back or "
                    f"update every referrer first)")

        for name in MARKDOWN_REFERRERS:
            if not repo.exists(name):
                yield Finding("docs-anchors/markdown", name, 0,
                              f"referrer {name} is missing")
                continue
            for i, line in enumerate(repo.lines(name), 1):
                if name == DESIGN and line.lstrip().startswith("#"):
                    continue                  # heading defines, not cites
                for ref in ANCHOR.findall(line):
                    if ref not in defined:
                        yield Finding(
                            "docs-anchors/markdown", name, i,
                            f"dangling DESIGN.md anchor {ref} (rename the "
                            f"section back or update the referrer)")

        for rel in repo.py_files():
            lines = repo.lines(rel)
            for i, line in enumerate(lines, 1):
                prev = lines[i - 2] if i >= 2 else ""
                joined = prev + " " + line
                offset = len(prev) + 1
                last_end = 0
                for m in ANCHOR.finditer(joined):
                    # attribution must sit between the previous anchor and
                    # this one — "(DESIGN.md §6.1); the paper's §5" leaves
                    # §5 unattributed even though DESIGN.md is nearby
                    window = joined[max(0, m.start() - _ATTRIBUTION_WINDOW,
                                        last_end):m.start()]
                    last_end = m.end()
                    if m.start() < offset:
                        continue              # prev line's anchor: already
                    if DESIGN not in window:  # reported on its own turn
                        continue              # paper/EXPERIMENTS citation
                    if m.group(0) not in defined:
                        yield Finding(
                            "docs-anchors/python", rel, i,
                            f"dangling DESIGN.md anchor {m.group(0)} "
                            f"(cited here but DESIGN.md has no such "
                            f"heading)")

    @staticmethod
    def _defined(repo: RepoIndex) -> Set[str]:
        out: Set[str] = set()
        for line in repo.lines(DESIGN):
            if line.lstrip().startswith("#"):
                out.update(ANCHOR.findall(line))
        return out
