"""Pure-jnp oracles for the Pallas kernels (also the CPU/dry-run path)."""

from repro.models.attention import (decode_attention as decode_ref,
                                    flash_attention as flash_ref,
                                    reference_attention)

__all__ = ["decode_ref", "flash_ref", "reference_attention"]
