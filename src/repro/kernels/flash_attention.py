"""Pallas TPU flash-attention (prefill): causal / windowed, GQA-native.

TPU adaptation notes (vs the paper's GPU serving stacks — FlashInfer/Triton):
no warps or shared-memory banking; instead the kernel is grid-blocked with
explicit VMEM tiles.  Block sizes default to (256, 512) so each tile's
working set — q (rep·bq·d) + k/v (bk·d) + scores (rep·bq·bk) f32 — stays well
under the ~16 MB VMEM budget, and all matmul dims are multiples of 128 for
MXU alignment.  The kv-block grid axis is 'arbitrary' (sequential) so the
online-softmax carry lives in VMEM scratch across kv steps.

GQA is native: the grid batches over (batch × kv_head) and the q tile carries
the ``rep = n_heads // n_kv_heads`` query heads that share the kv head, so K/V
tiles are fetched once per kv head (bandwidth = GQA's whole point).

Validated in interpret mode on CPU against ``ref.py`` (pure jnp oracle).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallascompat import tpu_compiler_params
from repro.models.attention import NEG_INF


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: Optional[int],
                  sq: int, skv: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                  # (rep, bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ()))) * scale
    # s: (rep, bq, bk)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < skv                                # kv padding
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_ref[...]                               # (rep, bq)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                 # (rep, bq, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())))
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        block_q: int = 256, block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D).

    ``interpret=True`` runs the kernel body on CPU (this container); on real
    TPU hardware pass interpret=False.
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    assert h % hkv == 0
    rep = h // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    q_pad = (-sq) % bq
    kv_pad = (-skv) % bk
    if q_pad:
        q = jnp.pad(q, [(0, 0), (0, q_pad), (0, 0), (0, 0)])
    if kv_pad:
        kv_p = [(0, 0), (0, kv_pad), (0, 0), (0, 0)]
        k, v = jnp.pad(k, kv_p), jnp.pad(v, kv_p)
    sq_p, skv_p = sq + q_pad, skv + kv_pad

    # (B·Hkv, rep, Sq, D) / (B·Hkv, Skv, D)
    qr = q.reshape(b, sq_p, hkv, rep, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * hkv, rep, sq_p, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, d)

    grid = (b * hkv, sq_p // bq, skv_p // bk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, sq=sq, skv=skv,
                               scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rep, bq, d), lambda bh, iq, ik: (bh, 0, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, bq, d),
                               lambda bh, iq, ik: (bh, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, rep, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, bq, d), jnp.float32),
            pltpu.VMEM((rep, bq), jnp.float32),
            pltpu.VMEM((rep, bq), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, hkv, rep, sq_p, d).transpose(0, 3, 1, 2, 4) \
        .reshape(b, sq_p, h, d)
    return out[:, :sq]
