"""Disaggregated prefill/decode executors (DESIGN.md §6.1-disagg).

Five families of tests:

1.  Sim analytics — ``DisaggTokenBucketExecutor`` reduces to
    prefill + transfer + decode exactly for a lone stream, the transfer
    cost model charges ``bytes = prompt_len * kv_bytes_per_token``, and
    the load snapshot splits prefill from decode headroom.
2.  Engine parity — ``DisaggEngineExecutor`` greedy outputs are
    bit-identical to the colocated ``Engine(paged=True)`` (and therefore
    to slot batching), property-tested over random workloads and pool
    geometries, including decode-pool preemption round-trips through the
    prefill engine.
3.  Handoff accounting — pages claimed by the prefill side and released
    to the decode side conserve both pool totals under churn, in the sim
    (property test, incl. ``go_offline`` mid-handoff) and in the engine
    (per-step conservation on both pools).
4.  Sim-vs-engine agreement — identical admit/deny sequences on identical
    decode-page budgets (both gate through ``paged_admit_ok`` with
    decode-side reservations).
5.  Preemption clocks — ``Engine._preempt`` resets the TTFT clock of the
    requeued request, and completed-request timestamps stay monotone
    (enqueued <= started <= first token <= finished) through preemption in
    both the colocated paged executor and the disagg pair.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Network, Node, NodePolicy
from repro.core.node import QueuedRequest
from repro.sim import (BackendProfile, DisaggTokenBucketExecutor, EventLoop,
                       make_profile)
from repro.sim.executor import pages_for
from repro.sim.workload import Request


def _qr(rid, prompt, output, t=0.0):
    return QueuedRequest(
        Request(rid=rid, origin="n", arrival=t, prompt_tokens=prompt,
                output_tokens=output, slo_s=600.0),
        enqueue_time=t, delegated=False, origin_node="n")


class _Harness:
    """A DisaggTokenBucketExecutor on a bare loop, recording completions."""

    def __init__(self, profile, prefill_profile=None, **kw):
        self.loop = EventLoop()
        self.ex = DisaggTokenBucketExecutor(profile, prefill_profile, **kw)
        self.done = {}
        self.ex.bind(self.loop, self._cb)

    def _cb(self, qr, started_at, first_token_at):
        self.done[qr.req.rid] = dict(finish=self.loop.now,
                                     started=started_at,
                                     first_token=first_token_at)


PROF = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                      max_concurrency=8, quality=0.5, kv_token_budget=4096)


# ---------------------------------------------------------------------------
# 1. sim analytics
# ---------------------------------------------------------------------------

class TestDisaggSimAnalytics:
    def test_single_request_is_prefill_plus_transfer_plus_decode(self):
        h = _Harness(PROF)
        assert h.ex.admit(_qr("a", 200, 500))
        h.loop.run()
        expected = (200 / PROF.prefill_tps + h.ex.transfer_s(200)
                    + 500 / PROF.decode_tps)
        rec = h.done["a"]
        assert rec["finish"] == pytest.approx(expected, rel=1e-6)
        # the prefill side emits the first token the moment prefill ends
        assert rec["first_token"] == pytest.approx(200 / PROF.prefill_tps,
                                                   rel=1e-6)
        assert rec["started"] <= rec["first_token"] <= rec["finish"]

    def test_transfer_cost_scales_with_prompt_bytes(self):
        ex = DisaggTokenBucketExecutor(PROF, kv_bytes_per_token=1000,
                                       transfer_bytes_per_s=1e6,
                                       transfer_base_s=0.5)
        # 2000 tokens * 1000 B / 1e6 B/s = 2 s on the wire + 0.5 s base
        assert ex.transfer_s(2000) == pytest.approx(2.5)
        assert ex.estimate(2000, 100) == pytest.approx(
            2000 / PROF.prefill_tps + 2.5 + 100 / PROF.decode_tps)

    def test_decode_share_recomputed_like_colocated(self):
        """k identical streams land on the decode side together and share
        decode throughput past the knee, exactly as colocated batching."""
        h = _Harness(PROF)
        k = 2 * PROF.saturation
        for i in range(k):
            assert h.ex.admit(_qr(f"r{i}", 100, 400))
        h.loop.run()
        expected = (100 / PROF.prefill_tps + h.ex.transfer_s(100)
                    + 400 / (PROF.decode_tps / 2.0))       # share = 2
        for rec in h.done.values():
            assert rec["finish"] == pytest.approx(expected, rel=1e-6)

    def test_load_splits_prefill_from_decode_headroom(self):
        h = _Harness(PROF)
        assert h.ex.admit(_qr("a", 1000, 1000))
        ld = h.ex.load()                                  # mid-prefill
        assert ld.prefill_kv_used == 1000
        assert ld.prefill_headroom < 1.0
        assert ld.kv_used == 0 and ld.decode_headroom == 1.0
        h.loop.run(until=0.2)                             # on the wire
        ld = h.ex.load()
        assert ld.transfer_inflight == 1
        assert ld.prefill_kv_used == 0                    # copy freed it
        h.loop.run(until=5.0)                             # mid-decode
        ld = h.ex.load()
        assert ld.transfer_inflight == 0
        assert ld.kv_used == 2000 and ld.decode_headroom < 1.0
        assert ld.prefill_headroom == 1.0
        h.loop.run()
        assert h.ex.load().kv_used == 0

    def test_oversized_request_admitted_when_empty(self):
        h = _Harness(PROF)
        assert h.ex.admit(_qr("huge", 8000, 8000))        # kv 16000 > 4096
        h.loop.run()
        assert "huge" in h.done


# ---------------------------------------------------------------------------
# 2. real-engine parity
# ---------------------------------------------------------------------------

_MODEL_CACHE = {}


def _smoke_model():
    if "cp" not in _MODEL_CACHE:
        import jax
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        _MODEL_CACHE["cp"] = (cfg, registry.init(jax.random.PRNGKey(0), cfg))
    return _MODEL_CACHE["cp"]


@pytest.fixture(scope="module")
def setup():
    return _smoke_model()


def _mk_reqs(seed, n=4, max_prompt=24, max_new_hi=10):
    from repro.serving import GenRequest
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(5, max_prompt + 1))
        out.append(GenRequest(
            rid=f"r{i}",
            tokens=rng.integers(2, 400, size=plen).astype(np.int32),
            max_new=int(rng.integers(2, max_new_hi + 1))))
    return out


def _drain_disagg(ex, reqs):
    """Admit with retries (the reservation gate may push back) and drain."""
    done = []
    ex.bind(None, lambda r, st_, ft: done.append(r))
    pending = list(reqs)
    while pending or ex.has_work():
        while pending and ex.admit(pending[0]):
            pending.pop(0)
        ex.step()
    return done


def _results_by_rid(reqs):
    return {r.rid: np.asarray(r.result) for r in reqs}


class TestDisaggEngineParity:
    def test_disagg_matches_colocated_paged(self, setup):
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = setup
        ref = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                     page_size=16)
        a = _results_by_rid(ref.serve(_mk_reqs(11)))
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                   page_size=16),
            Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                   page_size=16))
        b = _results_by_rid(_drain_disagg(ex, _mk_reqs(11)))
        assert set(a) == set(b)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert ex.prefill.stats.handoffs == len(a)
        assert ex.decode.stats.handoffs == len(a)
        assert ex.prefill.stats.handoff_bytes > 0

    def test_tight_decode_pool_preempts_and_stays_bit_identical(self, setup):
        """Decode-pool pressure preempts LIFO; the request recomputes via
        the prefill engine and outputs stay bit-identical to colocated."""
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = setup
        reqs = _mk_reqs(7, n=5, max_new_hi=16)
        ref = Engine(cfg, params, max_batch=2, bucket=16)
        a = _results_by_rid(ref.serve(_mk_reqs(7, n=5, max_new_hi=16)))
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                   page_size=16),
            Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                   page_size=16, num_pages=4))
        b = _results_by_rid(_drain_disagg(ex, reqs))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert ex.decode.stats.preempted > 0          # the tight pool bit
        # a preempted handoff crosses the wire again: more handoffs than
        # requests
        assert ex.prefill.stats.handoffs > len(a)
        assert ex.prefill.load_snapshot()["pages_used"] == 0
        assert ex.decode.load_snapshot()["pages_used"] == 0

    @given(page_size=st.sampled_from([8, 16]), pool=st.integers(4, 8),
           seed=st.integers(0, 10**6))
    @settings(max_examples=3, deadline=None)
    def test_random_churn_parity_disagg_vs_paged(self, page_size, pool, seed):
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = _smoke_model()
        ref = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=page_size, num_pages=pool)
        a = _results_by_rid(ref.serve(_mk_reqs(seed)))
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                   page_size=page_size),
            Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                   page_size=page_size, num_pages=pool))
        b = _results_by_rid(_drain_disagg(ex, _mk_reqs(seed)))
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])

    @pytest.mark.slow
    @given(page_size=st.sampled_from([8, 16, 32]), pool=st.integers(3, 10),
           seed=st.integers(0, 10**6),
           pre_batch=st.integers(1, 3), dec_batch=st.integers(2, 5))
    @settings(max_examples=8, deadline=None)
    def test_random_geometry_parity_deep(self, page_size, pool, seed,
                                         pre_batch, dec_batch):
        """Deeper sweep (``-m slow``): disagg == colocated paged == slot
        greedy outputs across random pool geometries and batch widths."""
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = _smoke_model()
        slot = Engine(cfg, params, max_batch=2, bucket=16)
        paged = Engine(cfg, params, max_batch=dec_batch, bucket=16,
                       paged=True, page_size=page_size, num_pages=pool)
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=pre_batch, bucket=16, paged=True,
                   page_size=page_size),
            Engine(cfg, params, max_batch=dec_batch, bucket=16, paged=True,
                   page_size=page_size, num_pages=pool))
        outs = [_results_by_rid(slot.serve(_mk_reqs(seed, n=5,
                                                    max_new_hi=14))),
                _results_by_rid(paged.serve(_mk_reqs(seed, n=5,
                                                     max_new_hi=14))),
                _results_by_rid(_drain_disagg(ex, _mk_reqs(seed, n=5,
                                                           max_new_hi=14)))]
        for rid in outs[0]:
            np.testing.assert_array_equal(outs[0][rid], outs[1][rid])
            np.testing.assert_array_equal(outs[0][rid], outs[2][rid])

    def test_requires_two_paged_engines(self, setup):
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = setup
        with pytest.raises(ValueError):
            DisaggEngineExecutor(
                Engine(cfg, params, max_batch=2, bucket=16),
                Engine(cfg, params, max_batch=2, bucket=16, paged=True))
        with pytest.raises(ValueError):
            DisaggEngineExecutor(
                Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                       page_size=8),
                Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                       page_size=16))


# ---------------------------------------------------------------------------
# 3. handoff accounting (pool conservation under churn)
# ---------------------------------------------------------------------------

class TestHandoffAccounting:
    @given(ops=st.lists(st.integers(1, 400), min_size=1, max_size=12),
           page=st.sampled_from([16, 32, 64]),
           dt=st.floats(0.0, 3.0))
    @settings(max_examples=25, deadline=None)
    def test_sim_pools_conserved_under_churn(self, ops, page, dt):
        """Random admits + time advancement: the prefill pool (strictly
        admission-gated) stays within its total, every snapshot keeps the
        headrooms in [0, 1] and the counts non-negative, and everything is
        reclaimed at drain.  Like the colocated sim backend, the decode
        side does not model preemption, so decode-page growth can
        transiently over-occupy the pool — that shows up as (clamped) zero
        decode headroom, not as a violated bound."""
        h = _Harness(PROF, page_size=page)
        t = 0.0
        for prompt in ops:
            h.ex.admit(_qr(f"p{t}-{prompt}", prompt, prompt, t=t))
            t += dt
            h.loop.run(until=t)
            ld = h.ex.load()
            assert 0 <= ld.prefill_kv_used <= ld.prefill_kv_budget
            assert ld.pages_used >= 0
            assert ld.kv_used == ld.pages_used * page
            assert ld.transfer_inflight >= 0
            assert 0.0 <= ld.prefill_headroom <= 1.0
            assert 0.0 <= ld.decode_headroom <= 1.0
            assert 0.0 <= ld.page_headroom <= 1.0
        h.loop.run()
        ld = h.ex.load()
        assert ld.pages_used == 0 and ld.prefill_kv_used == 0
        assert ld.transfer_inflight == 0

    def test_go_offline_mid_handoff_drains_with_pools_reclaimed(self):
        """Churn: a disagg node going offline with streams mid-prefill,
        mid-transfer, and mid-decode hands queued requests back to the
        network; everything already admitted drains to completion and both
        pools return to empty."""
        net = Network(mode="single", seed=0, init_balance=100.0)
        prof = BackendProfile(prefill_tps=2e3, decode_tps=50.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=4096)
        net.add_node(Node(
            "n1", prof, policy=NodePolicy(),
            executor_factory=lambda node: DisaggTokenBucketExecutor(
                node.profile, page_size=64)))
        net.add_node(Node("n2", make_profile(), policy=NodePolicy()))
        reqs = [Request(rid=f"r{i}", origin="n1", arrival=0.1 * i,
                        prompt_tokens=500, output_tokens=1000, slo_s=600.0)
                for i in range(10)]
        # t=5.0: the executor holds prefilling, transferring, and decoding
        # streams at once (500-token prompts take 0.25s to prefill and
        # ~60ms to transfer); queued requests must bounce to n2
        net.loop.schedule(5.0, lambda: net.nodes["n1"].go_offline())
        m = net.run(reqs, until=500.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == 10                          # nothing stranded
        assert net.nodes["n1"].queue_len == 0
        assert any(c.executor == "n2" for c in user)    # drained to the peer
        ld = net.nodes["n1"].executor.load()
        assert ld.pages_used == 0 and ld.prefill_kv_used == 0
        assert ld.transfer_inflight == 0
        for c in user:
            assert np.isfinite(c.ttft) and c.ttft >= 0
            assert np.isfinite(c.queue_wait) and c.queue_wait >= 0

    def test_engine_pools_conserved_every_step(self, setup):
        """Stepped churny disagg serving: pages_used + free_pages ==
        pages_total on BOTH engines at every executor step, and both pools
        fully drain."""
        from repro.serving import DisaggEngineExecutor, Engine
        cfg, params = setup
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                   page_size=8),
            Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                   page_size=8, num_pages=9))
        ex.bind(None, lambda r, st_, ft: None)
        pending = _mk_reqs(23, n=6, max_new_hi=12)
        while pending or ex.has_work():
            while pending and ex.admit(pending[0]):
                pending.pop(0)
            ex.step()
            for snap in (ex.prefill.load_snapshot(),
                         ex.decode.load_snapshot()):
                assert snap["pages_used"] + snap["free_pages"] \
                    == snap["pages_total"]
                assert snap["pages_used"] >= 0
        assert ex.prefill.load_snapshot()["pages_used"] == 0
        assert ex.decode.load_snapshot()["pages_used"] == 0


# ---------------------------------------------------------------------------
# 4. sim-vs-engine admission agreement
# ---------------------------------------------------------------------------

class TestSimEngineDisaggAgreement:
    def test_admission_decisions_agree_on_identical_page_budget(self, setup):
        """The simulated and real disagg executors must produce the same
        admit/deny sequence for the same decode-page budget — both gate on
        ``paged_admit_ok`` over the decode pool minus the reservations of
        every staging stream."""
        from repro.serving import DisaggEngineExecutor, Engine, GenRequest
        cfg, params = setup
        page, pool = 16, 8
        dec_prof = BackendProfile(prefill_tps=1e4, decode_tps=100.0,
                                  saturation=2, max_concurrency=8,
                                  quality=0.5, kv_token_budget=page * pool)
        pre_prof = BackendProfile(prefill_tps=1e4, decode_tps=100.0,
                                  saturation=2, max_concurrency=8,
                                  quality=0.5, kv_token_budget=64 * page)
        sim = _Harness(dec_prof, pre_prof, page_size=page)
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=8, bucket=16, paged=True,
                   page_size=page, num_pages=64),
            Engine(cfg, params, max_batch=8, bucket=16, paged=True,
                   page_size=page, num_pages=pool))
        ex.bind(None, lambda r, st_, ft: None)
        rng = np.random.default_rng(5)
        sim_dec, eng_dec = [], []
        for i, plen in enumerate((40, 30, 50, 20)):     # pages 3, 2, 4, 2
            sim_dec.append(sim.ex.admit(_qr(f"s{i}", plen, 64)))
            eng_dec.append(ex.admit(GenRequest(
                rid=f"e{i}", tokens=rng.integers(2, 400, size=plen)
                .astype(np.int32), max_new=64)))
        # 3 + 2 reserved, then 4 > 8 - 5 denied, then 2 fits
        assert sim_dec == eng_dec == [True, True, False, True]

    def test_estimate_monotone_in_decode_occupancy(self):
        h = _Harness(make_profile())
        prev = 0.0
        for i in range(10):
            est = h.ex.estimate(256, 512)
            assert est >= prev
            prev = est
            assert h.ex.admit(_qr(f"r{i}", 64, 64))
            h.loop.run(until=(i + 1) * 2.0)   # let streams reach decode


# ---------------------------------------------------------------------------
# 5. preemption resets the TTFT clock; timestamps stay monotone
# ---------------------------------------------------------------------------

class TestPreemptionClocks:
    def test_preempted_requests_have_clocks_reset(self, setup):
        """Regression: a preempt-and-requeued request must not carry the
        aborted attempt's started_at/first_token_at — a mid-flight metrics
        read (or the disagg executor re-routing it) would otherwise report
        a TTFT for tokens the user never kept."""
        from repro.serving import Engine
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                     page_size=16, num_pages=4)
        for r in _mk_reqs(7, n=5, max_new_hi=16):
            eng.submit(r)
        saw_preempted_requeue = False
        while eng.has_work():
            eng.step()
            if eng.stats.preempted > 0:
                q = eng.take_queued()
                for r in q:
                    # nothing in the queue may carry a stale stamp
                    assert r.started_at == 0.0 and r.first_token_at == 0.0
                saw_preempted_requeue = saw_preempted_requeue or bool(q)
                for r in reversed(q):
                    eng.requeue(r)
        assert eng.stats.preempted > 0
        assert saw_preempted_requeue

    @pytest.mark.parametrize("flavor", ["paged", "disagg"])
    def test_completion_timestamps_monotone_under_preemption(self, setup,
                                                             flavor):
        """queue_wait and ttft stay well-defined through preemption in both
        real executors: enqueued <= started <= first token <= finished, and
        the preempted request's final stamps come from its last (kept)
        attempt."""
        from repro.serving import DisaggEngineExecutor, Engine, EngineExecutor
        cfg, params = setup
        reqs = _mk_reqs(7, n=5, max_new_hi=16)
        if flavor == "paged":
            ex = EngineExecutor(Engine(cfg, params, max_batch=4, bucket=16,
                                       paged=True, page_size=16, num_pages=4))
            done = []
            ex.bind(None, lambda r, st_, ft: done.append((r, st_, ft)))
            for r in reqs:
                ex.engine.submit(r)      # bypass the gate: force pressure
            ex.drain()
            preempted = ex.engine.stats.preempted
        else:
            ex = DisaggEngineExecutor(
                Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                       page_size=16),
                Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                       page_size=16, num_pages=4))
            done = []
            ex.bind(None, lambda r, st_, ft: done.append((r, st_, ft)))
            pending = list(reqs)
            while pending or ex.has_work():
                while pending and ex.admit(pending[0]):
                    pending.pop(0)
                ex.step()
            preempted = ex.decode.stats.preempted
        assert preempted > 0
        assert len(done) == len(reqs)
        for r, started, first_tok in done:
            assert 0.0 < r.enqueued_at <= started <= first_tok \
                <= r.finished_at

    def test_sim_timestamps_monotone(self):
        h = _Harness(PROF)
        for i in range(6):
            assert h.ex.admit(_qr(f"r{i}", 200, 400))
        h.loop.run()
        for rec in h.done.values():
            assert rec["started"] <= rec["first_token"] <= rec["finish"]


# ---------------------------------------------------------------------------
# 6. phase-aware dispatch
# ---------------------------------------------------------------------------

class TestPhaseAwareRouting:
    def _net(self):
        """Three disagg nodes; n1's decode pool is saturated, n2 is idle.
        Policies always accept, duels off, so routing is deterministic."""
        from repro.core import DuelParams
        net = Network(mode="decentralized", seed=0, init_balance=100.0,
                      power_of_two=True, duel=DuelParams(p_d=0.0))
        pol = NodePolicy(accept_freq=1.0, target_utilization=100.0)
        small = BackendProfile(prefill_tps=1e4, decode_tps=100.0,
                               saturation=2, max_concurrency=8, quality=0.5,
                               kv_token_budget=1024)
        for nid in ("n0", "n1", "n2"):
            net.add_node(Node(
                nid, small, policy=pol,
                executor_factory=lambda node: DisaggTokenBucketExecutor(
                    node.profile)))
        return net

    def test_decode_heavy_request_avoids_decode_saturated_node(self):
        net = self._net()
        n1 = net.nodes["n1"]
        # saturate n1's decode budget and let the stream reach decode
        assert n1.executor.admit(_qr("fill", 24, 1000))
        net.loop.run(until=1.0)
        assert net.nodes["n1"].executor.load().decode_headroom == 0.0
        req = Request(rid="x", origin="n0", arrival=1.0, prompt_tokens=8,
                      output_tokens=900, slo_s=600.0)
        assert net.try_offload(net.nodes["n0"], req)
        net.loop.run(until=2.0)
        # power-of-two probed both peers and picked the phase-free one
        assert net.nodes["n2"].executor.load().active_streams > 0

    def test_prefill_pressure_scores_prompt_heavy_requests(self):
        net = self._net()
        n1, n2 = net.nodes["n1"], net.nodes["n2"]
        assert n1.executor.admit(_qr("fill", 1000, 8))   # prefill-saturated
        prompt_heavy = Request(rid="p", origin="n0", arrival=0.0,
                               prompt_tokens=900, output_tokens=10,
                               slo_s=600.0)
        decode_heavy = Request(rid="d", origin="n0", arrival=0.0,
                               prompt_tokens=10, output_tokens=900,
                               slo_s=600.0)
        # prompt-heavy traffic sees n1 as loaded, decode-heavy barely does
        assert net._phase_pressure(n1, prompt_heavy) > 0.9
        assert net._phase_pressure(n1, decode_heavy) < 0.1
        assert net._phase_pressure(n2, prompt_heavy) == 0.0
