"""Training substrate: AdamW semantics, microbatch equivalence, pipeline."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.training import (AdamWConfig, adamw_update, init_opt_state,
                            init_state, lr_schedule, make_train_step)
from repro.training import checkpoint as ckpt


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000, min_lr_ratio=1.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.ones((4, 4))}
        state = init_opt_state(params)
        _, _, m = adamw_update(cfg, params, {"w": jnp.full((4, 4), 100.0)},
                               state)
        assert float(m["grad_norm"]) == pytest.approx(400.0)

    def test_warmup_cosine(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(
            0.5, rel=0.05)
        assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(
            0.1, rel=0.05)

    def test_weight_decay_skips_vectors(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0)
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = init_opt_state(params)
        zero = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
        new, _, _ = adamw_update(cfg, params, zero, state)
        assert float(new["w"][0, 0]) < 1.0      # decayed
        assert float(new["b"][0]) == pytest.approx(1.0)   # not decayed


class TestTrainStep:
    def test_microbatch_equivalence(self):
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        opt = AdamWConfig(lr=1e-3)
        state = init_state(jax.random.PRNGKey(0), cfg)
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                        global_batch=8, seed=0))
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
        outs = []
        for mb in (1, 2, 4):
            s = jax.jit(make_train_step(cfg, opt, microbatches=mb,
                                        q_chunk=32, kv_chunk=32))
            new, _ = s(state, batch)
            outs.append(new["params"])
        for other in outs[1:]:
            for a, b in zip(jax.tree.leaves(outs[0]),
                            jax.tree.leaves(other)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=3e-5)

    def test_loss_decreases(self):
        cfg = get_config("starcoder2-7b").smoke().replace(dtype="float32")
        opt = AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=50)
        state = init_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(make_train_step(cfg, opt, q_chunk=32, kv_chunk=32))
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                        global_batch=8, seed=0))
        losses = []
        for i in range(25):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3


class TestCheckpoint:
    def test_roundtrip_and_shape_guard(self):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        with tempfile.NamedTemporaryFile(suffix=".msgpack") as f:
            ckpt.save(f.name, tree, step=7)
            restored, step = ckpt.restore(f.name, tree)
            assert step == 7
            for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            bad = {"a": jnp.zeros((3, 2)), "b": {"c": jnp.ones((4,))}}
            with pytest.raises(ValueError):
                ckpt.restore(f.name, bad)


class TestPipeline:
    def test_deterministic_and_shifted(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=1)
        p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
        b1, b2 = p1.batch(3), p2.batch(3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["tokens"][:, 1:],
                                      b1["labels"][:, :-1])

    @given(st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_tokens_in_range(self, step):
        cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=2, seed=0)
        b = TokenPipeline(cfg).batch(step)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < 64
