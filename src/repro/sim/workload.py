"""Piecewise-Poisson request workloads (paper Table 3).

Each node's user traffic is a piecewise-homogeneous Poisson process: a list of
``(t_start, t_end, mean_interarrival_s)`` intervals.  Request lengths are drawn
from a seeded lognormal-ish distribution mimicking OpenR1-Math-220k reasoning
prompts (long outputs, max_tokens 8192 per paper Appendix C).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: str
    origin: str            # node id where the user submitted it
    arrival: float         # sim time of user submission
    prompt_tokens: int
    output_tokens: int
    slo_s: float           # latency threshold for SLO attainment
    is_duel_extra: bool = False   # challenger / judge traffic (excluded from SLO)
    # cross-request prefix caching (DESIGN.md §6.1-prefix): requests from the
    # same application share a system prompt — ``prefix_id`` names it and
    # ``prefix_tokens`` is the shared-prefix length (<= prompt_tokens).
    # ``None`` means the whole prompt is unique.
    prefix_id: Optional[str] = None
    prefix_tokens: int = 0


@dataclass(frozen=True)
class ArrivalPhase:
    t_start: float
    t_end: float
    mean_interarrival: float   # 1/lambda, seconds


@dataclass
class WorkloadSpec:
    """Per-node arrival schedule, as in paper Table 3."""

    node_id: str
    phases: Sequence[ArrivalPhase]
    prompt_mean: int = 512
    output_mean: int = 2048       # reasoning traces are long
    max_tokens: int = 8192        # paper: max token length 8192
    slo_s: float = 300.0

    def arrivals(self, rng: np.random.Generator) -> List[Tuple[float, int, int]]:
        """Materialize (time, prompt_tokens, output_tokens) arrivals."""
        out: List[Tuple[float, int, int]] = []
        for ph in self.phases:
            t = ph.t_start
            while True:
                t += rng.exponential(ph.mean_interarrival)
                if t >= ph.t_end:
                    break
                p = int(np.clip(rng.lognormal(np.log(self.prompt_mean), 0.6), 16, 4096))
                o = int(np.clip(rng.lognormal(np.log(self.output_mean), 0.7), 32, self.max_tokens))
                out.append((t, p, o))
        out.sort(key=lambda x: x[0])
        return out


def make_requests(specs: Sequence[WorkloadSpec], seed: int) -> List[Request]:
    """Materialize the full multi-node workload deterministically."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for spec in specs:
        for i, (t, p, o) in enumerate(spec.arrivals(rng)):
            reqs.append(Request(
                rid=f"{spec.node_id}-r{i}", origin=spec.node_id, arrival=t,
                prompt_tokens=p, output_tokens=o, slo_s=spec.slo_s))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def make_zipf_prefix_requests(n: int, node_ids: Sequence[str], seed: int, *,
                              n_prefixes: int = 8, zipf_a: float = 1.3,
                              prefix_tokens: int = 256, suffix_mean: int = 32,
                              mean_interarrival: float = 0.5,
                              output_mean: int = 64,
                              slo_s: float = 60.0) -> List[Request]:
    """Zipf-shared-prefix workload (DESIGN.md §6.1-prefix).

    Each request draws one of ``n_prefixes`` shared system prompts with
    zipf(``zipf_a``) popularity (rank 1 most popular; the unbounded tail is
    clamped onto the last rank), prepends it to a short unique suffix, and
    lands on a uniformly random origin node with exponential interarrivals —
    the traffic shape where cross-request prefix caching and cache-affinity
    dispatch pay off: most prompts are mostly a prefix some node has warm.
    """
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t = 0.0
    for i in range(n):
        t += rng.exponential(mean_interarrival)
        rank = min(int(rng.zipf(zipf_a)), n_prefixes)
        suffix = max(1, int(rng.lognormal(np.log(suffix_mean), 0.4)))
        reqs.append(Request(
            rid=f"z{i}",
            origin=node_ids[int(rng.integers(len(node_ids)))],
            arrival=t,
            prompt_tokens=prefix_tokens + suffix,
            output_tokens=max(8, int(rng.lognormal(np.log(output_mean), 0.5))),
            slo_s=slo_s,
            prefix_id=f"sys-{rank}",
            prefix_tokens=prefix_tokens))
    return reqs


def uniform_phases(t_end: float, mean_interarrival: float) -> List[ArrivalPhase]:
    return [ArrivalPhase(0.0, t_end, mean_interarrival)]


def two_phase(split: float, t_end: float, ia1: float, ia2: float) -> List[ArrivalPhase]:
    return [ArrivalPhase(0.0, split, ia1), ArrivalPhase(split, t_end, ia2)]
