"""Pallas TPU flash-decode: one query token against a long KV cache.

Decode attention is HBM-bandwidth-bound (the whole KV cache streams through
once per token), so the kernel is shaped for streaming: the grid walks KV
blocks sequentially per (batch × kv_head), the online-softmax carry lives in
VMEM scratch, and the tiny (rep × d) output is written once at the end.
Sliding-window / partially-filled caches are handled by masking against
``cache_len`` (scalar-prefetched so the mask math happens on SREGs).

The seq-sharded distributed decode (shard_map + log-sum-exp combine, see
``repro.launch.sharding``) calls this kernel per shard on TPU; the jnp oracle
in ``ref.py`` is the interpret-mode / CPU path.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallascompat import tpu_compiler_params
from repro.models.attention import NEG_INF


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, bk: int, window: Optional[int], scale: float):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)
    cache_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (rep, d)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (rep, bk)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    mask = k_pos < cache_len
    if window is not None:
        mask &= k_pos >= cache_len - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_decode_tpu(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: Optional[int] = None,
                     block_k: int = 1024, interpret: bool = True) -> jax.Array:
    """q: (B, 1, H, D); caches: (B, S, Hkv, D); cache_len: () int32.

    Returns (B, 1, H, D).
    """
    b, _, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    assert h % hkv == 0
    rep = h // hkv
    bk = min(block_k, s)
    pad = (-s) % bk
    if pad:
        kv_p = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, kv_p)
        v_cache = jnp.pad(v_cache, kv_p)
    sp = s + pad

    qr = q.reshape(b, hkv, rep, d).reshape(b * hkv, rep, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, sp, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, sp, d)
    lens = jnp.broadcast_to(jnp.reshape(cache_len, (1,)), (1,)).astype(jnp.int32)

    grid = (b * hkv, sp // bk)
    kernel = functools.partial(_decode_kernel, bk=bk, window=window,
                               scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, rep, d), lambda bh, ik, lens: (bh, 0, 0)),
                pl.BlockSpec((1, bk, d), lambda bh, ik, lens: (bh, ik, 0)),
                pl.BlockSpec((1, bk, d), lambda bh, ik, lens: (bh, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, rep, d),
                                   lambda bh, ik, lens: (bh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, d), jnp.float32),
                pltpu.VMEM((rep,), jnp.float32),
                pltpu.VMEM((rep,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b * hkv, rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, hkv, rep, d).reshape(b, 1, h, d)
