"""Trace export: Perfetto ``trace_event`` JSON + latency breakdowns.

Two consumers of the same span stream (DESIGN.md §Observability):

* :func:`to_chrome_trace` / :func:`write_chrome_trace` — the Chrome
  ``trace_event`` array format that chrome://tracing and ui.perfetto.dev
  load directly.  The two clock domains become two Perfetto *processes*
  (``sim-time`` and ``wall-time``) so simulated seconds and wall seconds
  never share an axis; each node/executor id becomes a named thread.
  Interval spans are complete events (``ph: "X"``, microsecond ``ts`` /
  ``dur``); instants (``t0 == t1``: admissions, preemptions) are thread-
  scoped instant events (``ph: "i"``).
* :func:`latency_breakdown` / :func:`breakdown_report` — "where did this
  request's latency go?": per request, the stage spans in start order
  with durations, plus the covered total.  The sim-side lifecycle spans
  partition ``[arrival, finish]`` by construction, so the per-stage sums
  reconstruct ``CompletedRequest.latency`` (the ``--trace`` acceptance
  check and the smoke round-trip both assert this).
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List

from repro.obs.tracer import SIM, WALL, Span

_PROCESS = {SIM: (1, "sim-time"), WALL: (2, "wall-time")}


def to_chrome_trace(spans: Iterable[Span]) -> Dict[str, Any]:
    """Render spans as a Chrome ``trace_event`` payload (JSON-able dict).

    Wall-clock timestamps are rebased to the earliest wall span so the
    trace starts near zero; sim timestamps are already small seconds.
    """
    spans = list(spans)
    base = {SIM: 0.0, WALL: 0.0}
    walls = [s.t0 for s in spans if s.clock == WALL]
    if walls:
        base[WALL] = min(walls)

    events: List[Dict[str, Any]] = []
    tids: Dict[tuple, int] = {}
    for clock, (pid, pname) in sorted(_PROCESS.items()):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": pname}})

    for s in spans:
        pid, _ = _PROCESS.get(s.clock, _PROCESS[SIM])
        key = (pid, s.who)
        tid = tids.get(key)
        if tid is None:
            tid = tids[key] = len(tids) + 1
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": s.who or "-"}})
        ts = (s.t0 - base[s.clock]) * 1e6
        args = {"rid": s.rid, **s.attrs} if s.rid else dict(s.attrs)
        ev: Dict[str, Any] = {"name": s.name,
                              "cat": s.name.split(".", 1)[0],
                              "pid": pid, "tid": tid,
                              "ts": round(ts, 3), "args": args}
        if s.t1 <= s.t0:
            ev["ph"] = "i"
            ev["s"] = "t"          # thread-scoped instant
        else:
            ev["ph"] = "X"
            ev["dur"] = round((s.t1 - s.t0) * 1e6, 3)
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: Iterable[Span], path: str) -> Dict[str, Any]:
    """Serialize :func:`to_chrome_trace` to ``path``; returns the payload
    so callers can assert on what was written."""
    payload = to_chrome_trace(spans)
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def latency_breakdown(spans: Iterable[Span]) -> Dict[str, Dict[str, Any]]:
    """Per request id: stage durations (summed per span name, seconds),
    the covered ``total`` (earliest start to latest end), and the span
    count.  Batch-scoped spans (``rid == ""``) are excluded — they
    describe engine steps, not any one request."""
    groups: Dict[str, List[Span]] = {}
    for s in spans:
        if s.rid:
            groups.setdefault(s.rid, []).append(s)
    out: Dict[str, Dict[str, Any]] = {}
    for rid, ss in groups.items():
        ss.sort(key=lambda s: (s.t0, s.t1))
        stages: Dict[str, float] = {}
        for s in ss:
            stages[s.name] = stages.get(s.name, 0.0) + s.dur
        out[rid] = {"stages": stages,
                    "total": max(s.t1 for s in ss) - min(s.t0 for s in ss),
                    "spans": len(ss)}
    return out


def breakdown_report(spans: Iterable[Span], limit: int = 0) -> str:
    """The plain-text "where did this request's latency go?" report:
    one block per request (all of them, or the ``limit`` slowest), each
    stage with its duration and share of the covered total."""
    bd = latency_breakdown(spans)
    rids = sorted(bd, key=lambda r: -bd[r]["total"])
    if limit:
        rids = rids[:limit]
    lines: List[str] = []
    for rid in rids:
        entry = bd[rid]
        total = entry["total"]
        lines.append(f"{rid}: total {total * 1e3:.3f} ms "
                     f"({entry['spans']} spans)")
        for name, dur in sorted(entry["stages"].items(),
                                key=lambda kv: -kv[1]):
            share = dur / total if total > 0 else 0.0
            lines.append(f"  {name:<18s} {dur * 1e3:10.3f} ms "
                         f"{share:6.1%}")
    return "\n".join(lines)
