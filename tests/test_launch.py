"""Launch layer: sharding rules, input specs, HLO collective parsing.

The full 512-device lower+compile proof runs via
``python -m repro.launch.dryrun --all`` (results in experiments/*.jsonl);
here we unit-test the pieces on a small in-process mesh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import meshenv
from repro.configs import INPUT_SHAPES, get_config, grid
from repro.launch import sharding as sh
from repro.launch.specs import (batch_struct, input_specs, n_groups_of,
                                reduced_depth)


@pytest.fixture(scope="module")
def mesh():
    # single CPU device, but axis NAMES match production (sizes 1)
    return meshenv.make_mesh((1, 1), ("data", "model"))


class TestShardingRules:
    def test_param_spec_roles(self, mesh):
        assert sh.param_spec("layers/wq", (32, 4096, 4096), mesh) == \
            P(None, "data", "model")
        assert sh.param_spec("layers/wo", (32, 4096, 4096), mesh) == \
            P(None, "model", "data")
        assert sh.param_spec("embed", (151936, 4096), mesh) == \
            P("model", "data")
        assert sh.param_spec("layers/we_gate", (40, 16, 6144, 10752),
                             mesh) == P(None, "model", "data", None)
        assert sh.param_spec("final_norm/scale", (4096,), mesh) == P()

    def test_indivisible_axes_dropped(self):
        # 7 not divisible by any >1 axis — on a 1x1 mesh everything divides,
        # so exercise _trim directly with a fake 16-wide axis
        big = meshenv.make_mesh((1, 1), ("data", "model"))
        assert sh._fits(36, big, "model")     # 36 % 1 == 0
        assert sh._trim((("data",), None), (7, 8), big) == P(("data",), None)

    def test_cache_spec_seq_sharded(self, mesh):
        spec = sh.cache_spec("k", (36, 128, 32768, 8, 128), mesh)
        assert spec == P(None, ("data",), "model", None, None)
        assert sh.cache_spec("length", (), mesh) == P()
        assert sh.cache_spec("C", (6, 1, 4, 1024, 1024), mesh)[0] is None


class TestInputSpecs:
    @pytest.mark.parametrize("arch,shape", [
        ("qwen3-8b", "train_4k"), ("dbrx-132b", "decode_32k"),
        ("whisper-base", "prefill_32k"), ("xlstm-1.3b", "long_500k"),
        ("qwen2-vl-7b", "train_4k"), ("recurrentgemma-9b", "decode_32k"),
    ])
    def test_struct_shapes(self, arch, shape):
        specs = input_specs(arch, shape)
        shp = INPUT_SHAPES[shape]
        cfg = specs["cfg"]
        if shp.kind == "train":
            b = specs["batch"]
            lead = (b.get("tokens") or b.get("embeds")).shape[0]
            assert lead == shp.global_batch
            assert b["labels"].shape[1] == shp.seq_len
            assert "state" in specs
        elif shp.kind == "decode":
            assert specs["token"].shape == (shp.global_batch, 1)
            assert "cache" in specs
            leaves = jax.tree.leaves(specs["cache"])
            assert all(hasattr(x, "shape") for x in leaves)

    def test_long500k_dense_gets_window(self):
        cfg = get_config("qwen3-8b", "long_500k")
        assert cfg.sliding_window == 4096
        specs = input_specs("qwen3-8b", "long_500k")
        # window ring cache, not a 500k dense cache
        assert specs["cache"]["k"].shape[2] == 4096

    def test_long500k_ssm_native(self):
        cfg = get_config("xlstm-1.3b", "long_500k")
        assert cfg.sliding_window is None
        specs = input_specs("xlstm-1.3b", "long_500k")
        n = sum(x.size for x in jax.tree.leaves(specs["cache"])
                if hasattr(x, "size"))
        assert n < 1e9          # O(1)-in-seq state, not a 500k KV cache

    def test_grid_is_40(self):
        assert len(grid()) == 40

    def test_reduced_depth_groups(self):
        for arch in ("qwen3-32b", "recurrentgemma-9b", "xlstm-1.3b",
                     "whisper-base"):
            cfg = get_config(arch)
            r1 = reduced_depth(cfg, 1)
            r2 = reduced_depth(cfg, 2)
            assert r2.n_layers > r1.n_layers
            assert n_groups_of(cfg) >= 2


class TestCollectiveParser:
    def test_shapes_and_kinds(self):
        from repro.launch.dryrun import collective_bytes, _shape_bytes
        assert _shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
        assert _shape_bytes("(f32[8,8], u32[4])") == 8 * 8 * 4 + 4 * 4
        hlo = """
  %ag = bf16[32,1024]{1,0} all-gather(bf16[2,1024]{1,0} %p), dimensions={0}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum
  %cp = f32[64]{0} collective-permute(f32[64]{0} %y), source_target_pairs={{0,1}}
  %dot.3 = f32[16,16]{1,0} dot(f32[16,8] %a, f32[8,16] %b)
"""
        got = collective_bytes(hlo)
        assert got["all-gather"] == 32 * 1024 * 2
        assert got["all-reduce"] == 128 * 4
        assert got["collective-permute"] == 64 * 4
        assert got["all-to-all"] == 0

    def test_hbm_parser_skips_elementwise(self):
        from repro.launch.dryrun import hbm_traffic_bytes
        hlo = """
  %p0 = f32[128,128]{1,0} parameter(0)
  %p1 = f32[128,128]{1,0} parameter(1)
  %add.0 = f32[128,128]{1,0} add(f32[128,128] %p0, f32[128,128] %p1)
  %dot.0 = f32[128,128]{1,0} dot(%add.0, %p1), lhs_contracting_dims={1}
"""
        got = hbm_traffic_bytes(hlo)
        # only the dot counts: result + both operands = 3 * 128*128*4
        assert got == 3 * 128 * 128 * 4
