"""Network integration: routing workflow, modes, churn, chain consensus."""

import numpy as np
import pytest

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.core.gossip import PeerRecord
from repro.core.node import QueuedRequest
from repro.sim import (BackendProfile, DisaggTokenBucketExecutor,
                       WorkloadSpec, make_profile, make_requests, two_phase,
                       uniform_phases)
from repro.sim.executor import ExecutorLoad, make_load_digest
from repro.sim.servicemodel import DIGEST_PRESSURE_PRIOR
from repro.sim.workload import Request


def _specs(t_end=400.0, hot_ia=3.0):
    return [
        WorkloadSpec("node1", two_phase(t_end / 2, t_end, hot_ia, 20),
                     output_mean=4096, slo_s=300),
        WorkloadSpec("node2", uniform_phases(t_end, 20), output_mean=4096,
                     slo_s=300),
        WorkloadSpec("node3", uniform_phases(t_end, 20), output_mean=4096,
                     slo_s=300),
        WorkloadSpec("node4", uniform_phases(t_end, 20), output_mean=4096,
                     slo_s=300),
    ]


def _net(mode, ledger="shared", seed=0, p_d=0.1):
    net = Network(mode=mode, seed=seed, ledger_mode=ledger,
                  duel=DuelParams(p_d=p_d, k_judges=2), init_balance=100.0)
    for i in range(4):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.5 + 0.1 * i),
                          policy=NodePolicy(offload_util_threshold=0.8)))
    return net


class TestModes:
    def test_all_requests_complete_every_mode(self):
        reqs = make_requests(_specs(), seed=1)
        for mode in ("single", "centralized", "decentralized"):
            m = _net(mode).run(reqs, until=400.0)
            user = [c for c in m.completed if not c.is_duel_extra]
            assert len(user) == len(reqs), mode

    def test_single_never_delegates(self):
        m = _net("single").run(make_requests(_specs(), seed=1), until=400.0)
        assert m.delegation_rate() == 0.0

    def test_decentralized_beats_single_under_skew(self):
        reqs = make_requests(_specs(hot_ia=2.0), seed=2)
        lat = {}
        for mode in ("single", "decentralized"):
            m = _net(mode).run(reqs, until=400.0)
            lat[mode] = m.avg_latency()
        assert lat["decentralized"] < lat["single"]

    def test_centralized_at_least_as_good_as_single(self):
        reqs = make_requests(_specs(hot_ia=2.0), seed=3)
        m_s = _net("single").run(reqs, until=400.0)
        m_c = _net("centralized").run(reqs, until=400.0)
        assert m_c.avg_latency() <= m_s.avg_latency() * 1.05


class TestEconomics:
    def test_credit_conservation(self):
        """Mint - slashes == total credit across nodes + treasury."""
        net = _net("decentralized", p_d=0.3)
        reqs = make_requests(_specs(hot_ia=2.0), seed=4)
        net.run(reqs, until=400.0)
        view = net.shared_ledger.view
        slashed = sum(op.amount for op in net.shared_ledger.history
                      if op.kind == "slash")
        minted = sum(op.amount for op in net.shared_ledger.history
                     if op.kind == "mint")
        assert view.total() == pytest.approx(minted - slashed, rel=1e-9)

    def test_executors_earn(self):
        net = _net("decentralized")
        reqs = make_requests(_specs(hot_ia=2.0), seed=5)
        net.run(reqs, until=400.0)
        served_delegated = {n.id: n.served_delegated
                            for n in net.nodes.values()}
        assert sum(served_delegated.values()) > 0

    def test_chain_mode_matches_shared_mode_balances(self):
        reqs = make_requests(_specs(), seed=6)
        n1 = _net("decentralized", ledger="shared")
        n1.run(reqs, until=400.0)
        n2 = _net("decentralized", ledger="chain")
        n2.run(reqs, until=400.0)
        for nid in n1.nodes:
            assert n1.ledger_balance(nid) == pytest.approx(
                n2.ledger_balance(nid), abs=1e-6)
        assert all(c.verify_chain() for c in n2.chains.values())
        # majority confirmations on every finalized block
        assert all(k * 2 > len(n2.chains) for k in
                   n2.block_confirmations[len(n2.chains):])


class TestChurn:
    def test_offline_node_gets_no_new_work(self):
        net = _net("decentralized")
        net.loop.schedule(50.0, lambda: net.nodes["node4"].go_offline())
        reqs = make_requests(_specs(hot_ia=2.0), seed=7)
        net.run(reqs, until=400.0)
        late = [c for c in net.metrics.completed
                if c.executor == "node4" and c.finish > 200.0
                and c.delegated]
        assert len(late) == 0

    def test_user_traffic_rerouted_from_offline_origin(self):
        net = _net("decentralized")
        net.loop.schedule(10.0, lambda: net.nodes["node1"].go_offline())
        reqs = make_requests(_specs(), seed=8)
        m = net.run(reqs, until=400.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == len(reqs)

    def test_rejoin_serves_again(self):
        net = _net("decentralized")
        net.loop.schedule(20.0, lambda: net.nodes["node4"].go_offline())
        net.loop.schedule(120.0, lambda: net.nodes["node4"].go_online())
        reqs = make_requests(_specs(hot_ia=2.0), seed=9)
        net.run(reqs, until=400.0)
        served_after = [c for c in net.metrics.completed
                        if c.executor == "node4" and c.finish > 150.0]
        assert len(served_after) > 0


def _mini_net(mode="decentralized", n=2, accept_freq=1.0, **kw):
    net = Network(mode=mode, seed=0, duel=DuelParams(p_d=0.0, k_judges=0),
                  init_balance=100.0, **kw)
    for i in range(n):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.5),
                          policy=NodePolicy(accept_freq=accept_freq)))
    return net


def _req(rid="r", origin="node1", arrival=0.0, prompt=8, out=4):
    return Request(rid=rid, origin=origin, arrival=arrival,
                   prompt_tokens=prompt, output_tokens=out, slo_s=30.0)


class TestWaitAccounting:
    """Re-enqueues must preserve the request's original enqueue time:
    queue_wait counts from when the request first entered a queue, not
    from its latest hop."""

    def test_offload_preserves_enqueue_time(self):
        net = _mini_net()
        net.loop.run(until=7.0)
        # queued at node1 since t=2.0, offloaded at t=7.0
        assert net.try_offload(net.nodes["node1"], _req(), enqueued_at=2.0)
        net.loop.run()
        done = [c for c in net.metrics.completed if c.rid == "r"]
        assert len(done) == 1
        # the five seconds already spent queued at the origin must count
        assert done[0].queue_wait >= 5.0

    def test_churn_resubmit_preserves_enqueue_time(self):
        net = _mini_net()
        node1 = net.nodes["node1"]
        # a request sits queued (never admitted) at node1 from t=0
        node1.local_queue.append(
            QueuedRequest(_req(), 0.0, delegated=False, origin_node="node1"))
        net.loop.run(until=9.0)
        node1.go_offline()       # strands the queue -> resubmit_elsewhere
        net.loop.run()
        done = [c for c in net.metrics.completed if c.rid == "r"]
        assert len(done) == 1
        assert done[0].executor == "node2"
        assert done[0].queue_wait >= 9.0


class TestDrainLiveness:
    """`run()` must terminate even when every node is offline: the 5s
    resubmit/dispatch retries stop rescheduling once the drain begins."""

    def test_decentralized_drain_terminates_all_nodes_offline(self):
        net = _mini_net()
        for node in net.nodes.values():
            net.loop.schedule(1.0, node.go_offline)
        reqs = [_req(rid=f"r{i}", arrival=2.0 + i) for i in range(3)]
        m = net.run(reqs, until=20.0)     # regression: used to never return
        assert len([c for c in m.completed if not c.is_duel_extra]) == 0

    def test_centralized_drain_terminates_all_nodes_offline(self):
        net = _mini_net(mode="centralized")
        for node in net.nodes.values():
            net.loop.schedule(1.0, node.go_offline)
        reqs = [_req(rid=f"r{i}", arrival=2.0 + i) for i in range(3)]
        m = net.run(reqs, until=20.0)     # regression: used to never return
        assert len([c for c in m.completed if not c.is_duel_extra]) == 0


class TestTransferRateEMA:
    def test_out_of_order_samples_do_not_rewind_baseline(self):
        """A stale digest observed after a fresh probe must not rewind the
        per-node transfer-rate baseline."""
        net = _mini_net()
        net._observe_transfer_rate("n", 1.0, 1000)
        net._observe_transfer_rate("n", 2.0, 3000)    # db > 0: EMA updates
        ema = dict(net._transfer_rate_ema)
        assert ema
        net._observe_transfer_rate("n", 1.5, 500)     # stale: ignored
        assert net._transfer_rate_ema == ema
        assert net._transfer_obs["n"][0] == 2.0

    def test_decentralized_run_feeds_transfer_ema(self):
        """Regression: the EMA was only fed by the centralized `_est_wait`
        path, so decentralized routing never learned transfer rates.  Now
        probe responses and gossip digests both carry `handoff_bytes`
        samples."""
        net = Network(mode="decentralized", seed=0, init_balance=100.0,
                      duel=DuelParams(p_d=0.0, k_judges=0),
                      gossip_interval=0.5)
        pol = NodePolicy(accept_freq=1.0, offload_freq=1.0,
                         offload_queue_threshold=0)
        small = BackendProfile(prefill_tps=1e4, decode_tps=50.0,
                               saturation=2, max_concurrency=8, quality=0.5,
                               kv_token_budget=1024)
        for nid in ("n0", "n1", "n2"):
            net.add_node(Node(
                nid, small, policy=pol,
                executor_factory=lambda node: DisaggTokenBucketExecutor(
                    node.profile)))
        reqs = [Request(rid=f"r{i}", origin="n0", arrival=0.2 * i,
                        prompt_tokens=256, output_tokens=128, slo_s=600.0)
                for i in range(40)]
        net.run(reqs, until=30.0)
        assert net._transfer_rate_ema, \
            "no transfer-rate observations reached the EMA"


class TestGossipRouting:
    def test_digest_pressure_discounts_stale_digests(self):
        net = _mini_net()
        node1 = net.nodes["node1"]
        # node2 published a fully-saturated digest at t=0 (injected via a
        # merge, built through the sanctioned executor-layer projection)
        d = make_load_digest(ExecutorLoad(
            active_streams=2, queued_streams=0, pending_prefill_tokens=0,
            pending_decode_tokens=0, kv_used=100, kv_budget=100), 0.0)
        node1.view.merge([PeerRecord("node2", 99, True, "tcp://node2", 0.0,
                                     digest=d)])
        req = _req()
        fresh = net._digest_pressure(node1, "node2", req)
        assert fresh > 0.9                  # trusted while fresh
        net.loop.run(until=100.0)           # age the digest far past tau
        stale = net._digest_pressure(node1, "node2", req)
        assert stale == pytest.approx(DIGEST_PRESSURE_PRIOR, abs=0.01)
        # an unknown peer scores exactly the neutral prior
        assert net._digest_pressure(node1, "nobody", req) == \
            DIGEST_PRESSURE_PRIOR

    def test_routing_messages_accounting(self):
        net = _mini_net()
        assert net.routing_messages == 0
        net.msg_counts["probe"] += 3
        net.msg_counts["dispatch"] += 2
        net.msg_counts["bounce"] += 1
        assert net.routing_messages == 2 * 3 + 2 + 1


class TestChainResync:
    def test_offline_node_misses_blocks_then_catches_up(self):
        net = _net("decentralized", ledger="chain")
        net.loop.schedule(30.0, lambda: net.nodes["node4"].go_offline())
        net.loop.schedule(250.0, lambda: net.nodes["node4"].go_online())
        reqs = make_requests(_specs(hot_ia=2.0), seed=11)
        net.run(reqs, until=400.0)
        lens = {nid: len(c.blocks) for nid, c in net.chains.items()}
        # after resync all online chains converge and verify
        assert len(set(lens.values())) == 1, lens
        assert all(c.verify_chain() for c in net.chains.values())
