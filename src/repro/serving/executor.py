"""Real-engine backends behind the Executor contract (DESIGN.md §6.1).

Two executors wrap real JAX inference so the end-to-end driver in
``repro.launch.serve`` can treat it and the simulated token buckets
uniformly:

* ``EngineExecutor``       — one slot-based continuous-batching ``Engine``
                             (optionally paged) running both phases.
* ``SpecEngineExecutor``   — speculative decoding (DESIGN.md §6.1-spec):
                             wraps a spec-enabled paged ``Engine``
                             (draft/verify) and reports the online
                             acceptance model through
                             ``ExecutorLoad.expected_tokens_per_step`` so
                             dispatch can chase effective decode
                             throughput.
* ``DisaggEngineExecutor`` — disaggregated prefill/decode (DESIGN.md
                             §6.1-disagg): a prefill-role and a decode-role
                             paged ``Engine`` joined by page-granular KV
                             handoff (``Engine.extract_handoffs`` /
                             ``Engine.accept_handoff``); greedy outputs are
                             bit-identical to a colocated paged engine.

Both implement the same four-method contract as the simulated backends
(see ``repro.sim.executor`` for the full contract description):
``admit(item) -> bool``, ``load() -> ExecutorLoad``,
``estimate(prompt, output) -> seconds``, and ``bind(loop, on_complete)``
with the completion callback receiving ``(item, started_at,
first_token_at)``.

Minimal usage example (wall-clock: the caller pumps steps)::

    from repro.serving import Engine, EngineExecutor

    ex = EngineExecutor(Engine(cfg, params, max_batch=4))
    done = []
    ex.bind(None, lambda req, started, first_tok: done.append(req))
    assert ex.admit(GenRequest(rid="r0", tokens=prompt, max_new=16))
    while ex.has_work():
        ex.step()          # one iteration: sample, retire, admit, decode

Unlike the simulated backends there is no ambient event loop: the engines
run in wall-clock time, so callers pump ``step()`` or ``drain()``
themselves (the serving driver does this round-robin across nodes).
"""

from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional

from repro.obs import WALL, get_tracer, wall_now
from repro.serving.engine import Engine, EngineStats, GenRequest, KVHandoff
from repro.sim.executor import (Executor, ExecutorLoad, paged_admit_ok,
                                pages_for, spec_expected_tokens)


def _pending_gate(snap: Dict[str, int], item: GenRequest,
                  max_pending_tokens: Optional[int]) -> bool:
    """Shared admission backpressure: True when the queued-but-unstarted
    token backlog (plus this request) still fits ``max_pending_tokens``
    (None = unbounded; an empty queue always admits)."""
    if max_pending_tokens is None:
        return True
    pending = snap["queued_prompt_tokens"] + snap["queued_new_tokens"]
    return (snap["queued_streams"] == 0
            or pending + len(item.tokens) + item.max_new
            <= max_pending_tokens)


class EngineExecutor(Executor):
    def __init__(self, engine: Engine,
                 max_pending_tokens: Optional[int] = None,
                 gate_on_pages: bool = False) -> None:
        self.engine = engine
        # admission bound: queued-but-unstarted work the executor will hold
        # before pushing back on the caller (None = unbounded)
        self.max_pending_tokens = max_pending_tokens
        # paged engines only: push back at admit() time with the same
        # page-granularity rule the engine applies at prefill time
        # (repro.sim.executor.paged_admit_ok), so a caller that respects
        # admit() sees the identical notion of "full" as the simulated
        # TokenBucketExecutor in page mode
        self.gate_on_pages = gate_on_pages
        self._loop = None
        self._on_complete = None

    # ------------------------------------------------------------- interface
    @property
    def owner(self) -> str:   # type: ignore[override]
        """Trace identity forwards to the engine: its wall spans
        (``engine.prefill``/``engine.decode_step``) must carry the node id
        the Node binds onto this executor."""
        return self.engine.owner

    @owner.setter
    def owner(self, v: str) -> None:
        self.engine.owner = v

    @property
    def n_active(self) -> int:
        return self.engine.active_slots()

    def admit(self, item: GenRequest) -> bool:
        if self.gate_on_pages or self.max_pending_tokens is not None:
            snap = self.engine.load_snapshot()
            if self.gate_on_pages and self.engine.paged:
                resident = snap["active_streams"] + snap["queued_streams"] > 0
                if not paged_admit_ok(snap["free_pages"], len(item.tokens),
                                      snap["page_size"], resident=resident):
                    return False
            if not _pending_gate(snap, item, self.max_pending_tokens):
                return False
        self.engine.submit(item)
        return True

    def load(self) -> ExecutorLoad:
        snap = self.engine.load_snapshot()
        return ExecutorLoad(
            active_streams=snap["active_streams"],
            queued_streams=snap["queued_streams"],
            pending_prefill_tokens=snap["queued_prompt_tokens"],
            pending_decode_tokens=(snap["pending_decode_tokens"]
                                   + snap["queued_new_tokens"]),
            kv_used=snap["kv_used"],
            kv_budget=snap["kv_budget"],
            pages_used=snap["pages_used"],
            pages_total=snap["pages_total"],
            handoff_bytes=self.engine.stats.handoff_bytes,
            cache_hit_rate=float(snap["prefix_hit_rate"]),
            resident_prefixes=tuple(snap["resident_prefixes"]))

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        """Expected service seconds from the engine's measured prefill and
        decode throughput (wall time spent inside the respective jit calls,
        so admission/sampling overhead does not skew the rates)."""
        st = self.engine.stats
        if st.decode_tokens == 0 or st.decode_wall_s <= 0:
            return float("inf")      # no calibration data yet: probe-unknown
        t = output_tokens / (st.decode_tokens / st.decode_wall_s)
        if st.prefill_tokens > 0 and st.prefill_wall_s > 0:
            t += prompt_tokens / (st.prefill_tokens / st.prefill_wall_s)
        return t

    # ---------------------------------------------------------------- driving
    def has_work(self) -> bool:
        return self.engine.has_work()

    def engine_stats(self) -> EngineStats:
        """Aggregate engine counters (uniform across executor flavors)."""
        return self.engine.stats

    def step(self) -> List[GenRequest]:
        finished = self.engine.step()
        for r in finished:
            if self._on_complete is not None:
                self._on_complete(r, r.started_at, r.first_token_at)
        return finished

    def drain(self) -> List[GenRequest]:
        done: List[GenRequest] = []
        while self.engine.has_work():
            done.extend(self.step())
        return done


class SpecEngineExecutor(EngineExecutor):
    """Speculative decoding behind the Executor contract (DESIGN.md
    §6.1-spec): an ``EngineExecutor`` over a spec-enabled paged ``Engine``
    (``Engine(spec_draft=..., spec_k=...)``).

    Admission, paging, and driving are inherited unchanged — speculation
    changes how fast decode *drains*, not how much KV a resident stream
    holds.  What this subclass adds is the acceptance model: ``load()``
    reports ``expected_tokens_per_step`` from the engine's online
    acceptance-rate EMA (seeded from the same ``SPEC_ALPHA0`` constant the
    simulated ``SpecTokenBucketExecutor`` defaults to, so a fresh sim node
    and a fresh engine node score identically), and ``estimate()`` charges
    the measured draft wall time next to the target-side decode wall.
    """

    def __init__(self, engine: Engine,
                 max_pending_tokens: Optional[int] = None,
                 gate_on_pages: bool = False) -> None:
        if not engine.spec:
            raise ValueError("SpecEngineExecutor requires a spec-enabled "
                             "engine (Engine(spec_draft=..., spec_k=...))")
        super().__init__(engine, max_pending_tokens, gate_on_pages)

    def expected_tokens_per_step(self) -> float:
        return spec_expected_tokens(self.engine.spec_alpha,
                                    self.engine.spec_k)

    def load(self) -> ExecutorLoad:
        return replace(super().load(),
                       expected_tokens_per_step=self.expected_tokens_per_step())

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        """Measured-rate estimate including the draft's cost: emitted
        tokens over target verify wall PLUS draft wall, so a draft that
        doesn't pay for itself shows up in routing estimates."""
        st = self.engine.stats
        wall = st.decode_wall_s + st.draft_wall_s
        if st.decode_tokens == 0 or wall <= 0:
            return float("inf")      # no calibration data yet: probe-unknown
        t = output_tokens / (st.decode_tokens / wall)
        if st.prefill_tokens > 0 and st.prefill_wall_s > 0:
            t += prompt_tokens / (st.prefill_tokens / st.prefill_wall_s)
        return t


class DisaggEngineExecutor(Executor):
    """Disaggregated prefill/decode over two paged engines (DESIGN.md
    §6.1-disagg).

    The **prefill engine** admits queued requests, runs their prompt
    prefill, samples the first output token (disagg serves TTFT from the
    prefill node), and decodes that token once so its KV is in pages; each
    such row is then popped as a ``KVHandoff`` — a page-granular copy of
    its KV plus the next-token logits — freeing the prefill pool for the
    next prompts.  Handoffs land FIFO on the **decode engine**
    (``Engine.accept_handoff``), which scatters the pages into its own
    pool and resumes decoding exactly where the prefill engine stopped, so
    greedy outputs are bit-identical to a colocated ``Engine(paged=True)``.

    Admission reserves the prompt's pages against the *decode* pool
    (DistServe-style: a transfer you can never land is wasted work), using
    the same ``paged_admit_ok`` rule as the simulated
    ``DisaggTokenBucketExecutor``, so sim and engine admission decisions
    agree on identical page budgets.  Decode-side preemptions (LIFO, pool
    pressure) are routed back through the prefill engine for a recompute
    handoff rather than letting the decode engine re-prefill them itself.
    """

    def __init__(self, prefill_engine: Engine, decode_engine: Engine,
                 max_pending_tokens: Optional[int] = None) -> None:
        if not (prefill_engine.paged and decode_engine.paged):
            raise ValueError("disaggregation requires two paged engines")
        if prefill_engine.page_size != decode_engine.page_size:
            raise ValueError("prefill/decode page_size mismatch")
        self.prefill = prefill_engine
        self.decode = decode_engine
        self.page_size = decode_engine.page_size
        self.max_pending_tokens = max_pending_tokens
        self._pending: List[KVHandoff] = []      # extracted, not yet landed
        self._reserved: Dict[str, int] = {}      # rid -> decode pages held
        self._loop = None
        self._on_complete = None

    # ------------------------------------------------------------- interface
    @property
    def owner(self) -> str:   # type: ignore[override]
        return self.prefill.owner

    @owner.setter
    def owner(self, v: str) -> None:
        # both phase engines speak for the same node in traces
        self.prefill.owner = v
        self.decode.owner = v

    @property
    def n_active(self) -> int:
        return self.prefill.active_slots() + self.decode.active_slots()

    def admit(self, item: GenRequest) -> bool:
        snap = self.decode.load_snapshot()
        free_eff = snap["free_pages"] - sum(self._reserved.values())
        resident = (snap["active_streams"] + snap["queued_streams"] > 0
                    or bool(self._reserved))
        if not paged_admit_ok(free_eff, len(item.tokens), self.page_size,
                              resident=resident):
            return False
        if self.max_pending_tokens is not None and not _pending_gate(
                self.prefill.load_snapshot(), item, self.max_pending_tokens):
            return False
        self._reserved[item.rid] = pages_for(len(item.tokens), self.page_size)
        self.prefill.submit(item)
        return True

    def load(self) -> ExecutorLoad:
        ps = self.prefill.load_snapshot()
        ds = self.decode.load_snapshot()
        return ExecutorLoad(
            active_streams=ps["active_streams"] + ds["active_streams"],
            queued_streams=ps["queued_streams"] + ds["queued_streams"],
            pending_prefill_tokens=ps["queued_prompt_tokens"],
            pending_decode_tokens=(
                ds["pending_decode_tokens"] + ds["queued_new_tokens"]
                + ps["pending_decode_tokens"] + ps["queued_new_tokens"]
                + sum(h.req.max_new - len(h.out) for h in self._pending)),
            kv_used=ds["kv_used"], kv_budget=ds["kv_budget"],
            pages_used=ds["pages_used"], pages_total=ds["pages_total"],
            prefill_kv_used=ps["kv_used"], prefill_kv_budget=ps["kv_budget"],
            transfer_inflight=len(self._pending),
            handoff_bytes=self.prefill.stats.handoff_bytes,
            # the decode pool is where KV lives long-term, so its cache is
            # what affinity routing should chase (DESIGN.md §6.1-prefix)
            cache_hit_rate=float(ds["prefix_hit_rate"]),
            resident_prefixes=tuple(ds["resident_prefixes"]))

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        """Phase-split estimate: prompt at the prefill engine's measured
        prefill rate, output at the decode engine's measured decode rate
        (the page scatter/gather of the handoff itself rides inside those
        walls)."""
        dst = self.decode.stats
        if dst.decode_tokens == 0 or dst.decode_wall_s <= 0:
            return float("inf")      # no calibration data yet: probe-unknown
        t = output_tokens / (dst.decode_tokens / dst.decode_wall_s)
        pst = self.prefill.stats
        if pst.prefill_tokens > 0 and pst.prefill_wall_s > 0:
            t += prompt_tokens / (pst.prefill_tokens / pst.prefill_wall_s)
        return t

    # ---------------------------------------------------------------- driving
    def has_work(self) -> bool:
        return (self.prefill.has_work() or self.decode.has_work()
                or bool(self._pending))

    def engine_stats(self) -> EngineStats:
        """Both engines' counters summed (peaks maxed) — the uniform view
        the serving driver prints."""
        a, b = self.prefill.stats, self.decode.stats
        return replace(
            EngineStats(**{f: getattr(a, f) + getattr(b, f)
                           for f in EngineStats.__dataclass_fields__}),
            peak_resident=max(a.peak_resident, b.peak_resident),
            # a handoff is one transfer even though both ends count it
            handoffs=a.handoffs, handoff_bytes=a.handoff_bytes)

    def step(self) -> List[GenRequest]:
        """One disagg iteration: pump the prefill engine, extract handoffs,
        pump the decode engine, land the extracted handoffs, and route
        decode-side preemptions back to the prefill side.

        The extract -> decode -> land order makes the handoff copy
        asynchronous: ``extract_handoffs`` only DISPATCHES the device-side
        page gather (jax async dispatch returns before the copy runs), so
        the gather overlaps the decode engine's step instead of being
        forced inside its decode wall; the landed rows join the next
        iteration's batch.  Byte accounting is unchanged — both ends still
        count ``h.kv_bytes`` when the handoff object passes through."""
        finished: List[GenRequest] = []
        if self.prefill.has_work():
            finished.extend(self.prefill.step())   # may finish on prefill
        # the decode engine's prefix_pin tells the extract which leading
        # pages it already holds cached (DESIGN.md §6.1-prefix): those are
        # pinned against eviction, skipped by the gather, and excluded
        # from both ends' handoff_bytes
        handoffs = self.prefill.extract_handoffs(self.decode.prefix_pin)
        if handoffs:
            tr = get_tracer()
            if tr.enabled:
                t = wall_now()
                for h in handoffs:
                    tr.event("disagg.handoff", h.req.rid, self.owner, t,
                             clock=WALL, bytes=h.kv_bytes,
                             cached_tokens=h.cached_tokens)
        self._pending.extend(handoffs)
        if self.decode.has_work():
            finished.extend(self.decode.step())    # overlaps pending copies
        while self._pending and self.decode.accept_handoff(self._pending[0]):
            h = self._pending.pop(0)
            self._reserved.pop(h.req.rid, None)    # reservation -> real pages
        # decode-pool preemptions recompute via the prefill side, with the
        # decode pages they will need again re-reserved; reversed because
        # requeue() head-inserts — the oldest victim must end up first so
        # the LIFO policy's "oldest admission makes progress" is preserved
        for r in reversed(self.decode.take_queued()):
            self._reserved[r.rid] = pages_for(len(r.tokens), self.page_size)
            self.prefill.requeue(r)
        for r in finished:
            self._reserved.pop(r.rid, None)        # incl. finished-on-prefill
            if self._on_complete is not None:
                self._on_complete(r, r.started_at, r.first_token_at)
        return finished

    def drain(self) -> List[GenRequest]:
        done: List[GenRequest] = []
        while self.has_work():
            done.extend(self.step())
        return done
