"""Network integration: routing workflow, modes, churn, chain consensus."""

import numpy as np
import pytest

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import (WorkloadSpec, make_profile, make_requests, two_phase,
                       uniform_phases)


def _specs(t_end=400.0, hot_ia=3.0):
    return [
        WorkloadSpec("node1", two_phase(t_end / 2, t_end, hot_ia, 20),
                     output_mean=4096, slo_s=300),
        WorkloadSpec("node2", uniform_phases(t_end, 20), output_mean=4096,
                     slo_s=300),
        WorkloadSpec("node3", uniform_phases(t_end, 20), output_mean=4096,
                     slo_s=300),
        WorkloadSpec("node4", uniform_phases(t_end, 20), output_mean=4096,
                     slo_s=300),
    ]


def _net(mode, ledger="shared", seed=0, p_d=0.1):
    net = Network(mode=mode, seed=seed, ledger_mode=ledger,
                  duel=DuelParams(p_d=p_d, k_judges=2), init_balance=100.0)
    for i in range(4):
        net.add_node(Node(f"node{i+1}", make_profile(quality=0.5 + 0.1 * i),
                          policy=NodePolicy(offload_util_threshold=0.8)))
    return net


class TestModes:
    def test_all_requests_complete_every_mode(self):
        reqs = make_requests(_specs(), seed=1)
        for mode in ("single", "centralized", "decentralized"):
            m = _net(mode).run(reqs, until=400.0)
            user = [c for c in m.completed if not c.is_duel_extra]
            assert len(user) == len(reqs), mode

    def test_single_never_delegates(self):
        m = _net("single").run(make_requests(_specs(), seed=1), until=400.0)
        assert m.delegation_rate() == 0.0

    def test_decentralized_beats_single_under_skew(self):
        reqs = make_requests(_specs(hot_ia=2.0), seed=2)
        lat = {}
        for mode in ("single", "decentralized"):
            m = _net(mode).run(reqs, until=400.0)
            lat[mode] = m.avg_latency()
        assert lat["decentralized"] < lat["single"]

    def test_centralized_at_least_as_good_as_single(self):
        reqs = make_requests(_specs(hot_ia=2.0), seed=3)
        m_s = _net("single").run(reqs, until=400.0)
        m_c = _net("centralized").run(reqs, until=400.0)
        assert m_c.avg_latency() <= m_s.avg_latency() * 1.05


class TestEconomics:
    def test_credit_conservation(self):
        """Mint - slashes == total credit across nodes + treasury."""
        net = _net("decentralized", p_d=0.3)
        reqs = make_requests(_specs(hot_ia=2.0), seed=4)
        net.run(reqs, until=400.0)
        view = net.shared_ledger.view
        slashed = sum(op.amount for op in net.shared_ledger.history
                      if op.kind == "slash")
        minted = sum(op.amount for op in net.shared_ledger.history
                     if op.kind == "mint")
        assert view.total() == pytest.approx(minted - slashed, rel=1e-9)

    def test_executors_earn(self):
        net = _net("decentralized")
        reqs = make_requests(_specs(hot_ia=2.0), seed=5)
        net.run(reqs, until=400.0)
        served_delegated = {n.id: n.served_delegated
                            for n in net.nodes.values()}
        assert sum(served_delegated.values()) > 0

    def test_chain_mode_matches_shared_mode_balances(self):
        reqs = make_requests(_specs(), seed=6)
        n1 = _net("decentralized", ledger="shared")
        n1.run(reqs, until=400.0)
        n2 = _net("decentralized", ledger="chain")
        n2.run(reqs, until=400.0)
        for nid in n1.nodes:
            assert n1.ledger_balance(nid) == pytest.approx(
                n2.ledger_balance(nid), abs=1e-6)
        assert all(c.verify_chain() for c in n2.chains.values())
        # majority confirmations on every finalized block
        assert all(k * 2 > len(n2.chains) for k in
                   n2.block_confirmations[len(n2.chains):])


class TestChurn:
    def test_offline_node_gets_no_new_work(self):
        net = _net("decentralized")
        net.loop.schedule(50.0, lambda: net.nodes["node4"].go_offline())
        reqs = make_requests(_specs(hot_ia=2.0), seed=7)
        net.run(reqs, until=400.0)
        late = [c for c in net.metrics.completed
                if c.executor == "node4" and c.finish > 200.0
                and c.delegated]
        assert len(late) == 0

    def test_user_traffic_rerouted_from_offline_origin(self):
        net = _net("decentralized")
        net.loop.schedule(10.0, lambda: net.nodes["node1"].go_offline())
        reqs = make_requests(_specs(), seed=8)
        m = net.run(reqs, until=400.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == len(reqs)

    def test_rejoin_serves_again(self):
        net = _net("decentralized")
        net.loop.schedule(20.0, lambda: net.nodes["node4"].go_offline())
        net.loop.schedule(120.0, lambda: net.nodes["node4"].go_online())
        reqs = make_requests(_specs(hot_ia=2.0), seed=9)
        net.run(reqs, until=400.0)
        served_after = [c for c in net.metrics.completed
                        if c.executor == "node4" and c.finish > 150.0]
        assert len(served_after) > 0


class TestChainResync:
    def test_offline_node_misses_blocks_then_catches_up(self):
        net = _net("decentralized", ledger="chain")
        net.loop.schedule(30.0, lambda: net.nodes["node4"].go_offline())
        net.loop.schedule(250.0, lambda: net.nodes["node4"].go_online())
        reqs = make_requests(_specs(hot_ia=2.0), seed=11)
        net.run(reqs, until=400.0)
        lens = {nid: len(c.blocks) for nid, c in net.chains.items()}
        # after resync all online chains converge and verify
        assert len(set(lens.values())) == 1, lens
        assert all(c.verify_chain() for c in net.chains.values())
