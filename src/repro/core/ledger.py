"""Credit-based transaction system (paper §4.1, Table 1).

Each node keeps a local *Credit Block Chain*: hash-linked blocks of credit
operations (stake / unstake / reward / transfer / slash / mint), signed by the
proposer.  A block is *finalized* once a majority of peers validate it and
append it to their local chains (``network.py`` drives broadcast + votes).

Double-spending is impossible by construction: every validator replays the
operations against its own balance view and rejects blocks that would drive
any balance or stake negative; conflicting histories diverge at the hash chain
and are detectable immediately.

The paper (§C) also uses a *shared ledger* fast path at experiment scale; we
provide both (``SharedLedger`` has the same op API without chain overhead).
"""

from __future__ import annotations

import hashlib
import hmac
import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

GENESIS_ID = "0" * 16

OP_KINDS = ("mint", "stake", "unstake", "transfer", "reward", "slash")


@dataclass(frozen=True)
class CreditOp:
    """One credit-related record inside a block."""

    kind: str            # one of OP_KINDS
    src: str             # paying / staking node ("" for mint)
    dst: str             # receiving node ("" for stake/unstake/slash)
    amount: float
    ref: str = ""        # request id / duel id this op settles

    def to_json(self) -> dict:
        return {"kind": self.kind, "src": self.src, "dst": self.dst,
                "amount": self.amount, "ref": self.ref}


@dataclass(frozen=True)
class CreditBlock:
    """Paper Table 1: Block ID, Parent ID, Timestamp, Operations, Proposer, Signature."""

    block_id: str
    parent_id: str
    timestamp: float
    operations: Tuple[CreditOp, ...]
    proposer: str
    signature: str

    @staticmethod
    def content_hash(parent_id: str, timestamp: float, ops: Sequence[CreditOp],
                     proposer: str) -> str:
        payload = json.dumps({
            "parent": parent_id, "ts": round(timestamp, 6),
            "ops": [o.to_json() for o in ops], "proposer": proposer,
        }, sort_keys=True).encode()
        return hashlib.sha256(payload).hexdigest()[:16]


def sign(secret: bytes, block_id: str) -> str:
    """HMAC-SHA256 stand-in for an ed25519 signature (see DESIGN.md §6.3)."""
    return hmac.new(secret, block_id.encode(), hashlib.sha256).hexdigest()[:16]


def verify_signature(secret: bytes, block: CreditBlock) -> bool:
    return hmac.compare_digest(sign(secret, block.block_id), block.signature)


class BalanceView:
    """Replayable balance + stake state machine shared by both ledgers."""

    def __init__(self) -> None:
        self.balance: Dict[str, float] = {}
        self.stake: Dict[str, float] = {}

    def copy(self) -> "BalanceView":
        v = BalanceView()
        v.balance = dict(self.balance)
        v.stake = dict(self.stake)
        return v

    def apply(self, op: CreditOp, check: bool = True) -> None:
        b, s = self.balance, self.stake
        if op.kind not in OP_KINDS:
            raise LedgerError(f"unknown op kind {op.kind!r}")
        if op.amount < 0:
            raise LedgerError("negative amount")
        if op.kind == "mint":
            b[op.dst] = b.get(op.dst, 0.0) + op.amount
        elif op.kind == "stake":
            if check and b.get(op.src, 0.0) < op.amount - 1e-9:
                raise LedgerError(f"{op.src} stakes {op.amount} > balance {b.get(op.src, 0.0)}")
            b[op.src] = b.get(op.src, 0.0) - op.amount
            s[op.src] = s.get(op.src, 0.0) + op.amount
        elif op.kind == "unstake":
            if check and s.get(op.src, 0.0) < op.amount - 1e-9:
                raise LedgerError(f"{op.src} unstakes {op.amount} > stake {s.get(op.src, 0.0)}")
            s[op.src] = s.get(op.src, 0.0) - op.amount
            b[op.src] = b.get(op.src, 0.0) + op.amount
        elif op.kind in ("transfer", "reward"):
            if check and b.get(op.src, 0.0) < op.amount - 1e-9:
                raise LedgerError(
                    f"double-spend: {op.src} pays {op.amount} > balance {b.get(op.src, 0.0)}")
            b[op.src] = b.get(op.src, 0.0) - op.amount
            b[op.dst] = b.get(op.dst, 0.0) + op.amount
        elif op.kind == "slash":
            # burn from stake (duel loser penalty)
            if check and s.get(op.src, 0.0) < op.amount - 1e-9:
                raise LedgerError(f"slash {op.amount} > stake {s.get(op.src, 0.0)}")
            s[op.src] = s.get(op.src, 0.0) - op.amount

    def total(self) -> float:
        return sum(self.balance.values()) + sum(self.stake.values())


class LedgerError(Exception):
    pass


class CreditChain:
    """A node's local credit block chain (full protocol path)."""

    def __init__(self, owner: str) -> None:
        self.owner = owner
        self.blocks: List[CreditBlock] = []
        self.view = BalanceView()
        self._ids = {GENESIS_ID}

    @property
    def head(self) -> str:
        return self.blocks[-1].block_id if self.blocks else GENESIS_ID

    def propose(self, ops: Sequence[CreditOp], timestamp: float,
                secret: bytes) -> CreditBlock:
        """Build + sign a block on the local head (does NOT append)."""
        ops = tuple(ops)
        bid = CreditBlock.content_hash(self.head, timestamp, ops, self.owner)
        return CreditBlock(block_id=bid, parent_id=self.head, timestamp=timestamp,
                           operations=ops, proposer=self.owner,
                           signature=sign(secret, bid))

    def validate(self, block: CreditBlock, proposer_secret: Optional[bytes] = None
                 ) -> Tuple[bool, str]:
        """Independent peer validation (paper: 'independently validate')."""
        if block.parent_id != self.head:
            return False, f"parent {block.parent_id} != head {self.head}"
        expect = CreditBlock.content_hash(block.parent_id, block.timestamp,
                                          block.operations, block.proposer)
        if expect != block.block_id:
            return False, "tampered content (hash mismatch)"
        if proposer_secret is not None and not verify_signature(proposer_secret, block):
            return False, "bad signature"
        trial = self.view.copy()
        try:
            for op in block.operations:
                trial.apply(op)
        except LedgerError as e:
            return False, str(e)
        return True, "ok"

    def append(self, block: CreditBlock) -> None:
        ok, why = self.validate(block)
        if not ok:
            raise LedgerError(f"append rejected: {why}")
        for op in block.operations:
            self.view.apply(op)
        self.blocks.append(block)
        self._ids.add(block.block_id)

    def verify_chain(self) -> bool:
        """Full-chain audit: hash links + replay from genesis."""
        parent = GENESIS_ID
        replay = BalanceView()
        for blk in self.blocks:
            if blk.parent_id != parent:
                return False
            if CreditBlock.content_hash(blk.parent_id, blk.timestamp,
                                        blk.operations, blk.proposer) != blk.block_id:
                return False
            try:
                for op in blk.operations:
                    replay.apply(op)
            except LedgerError:
                return False
            parent = blk.block_id
        return (replay.balance == self.view.balance and replay.stake == self.view.stake)

    # convenience accessors -------------------------------------------------
    def balance_of(self, node: str) -> float:
        return self.view.balance.get(node, 0.0)

    def stake_of(self, node: str) -> float:
        return self.view.stake.get(node, 0.0)

    def stakes(self) -> Dict[str, float]:
        return dict(self.view.stake)


class SharedLedger:
    """Paper §C fast path: one shared balance view, same op API."""

    def __init__(self) -> None:
        self.view = BalanceView()
        self.history: List[CreditOp] = []

    def apply(self, ops: Iterable[CreditOp]) -> None:
        ops = list(ops)
        trial = self.view.copy()
        for op in ops:                 # atomic: all-or-nothing
            trial.apply(op)
        for op in ops:
            self.view.apply(op)
        self.history.extend(ops)

    def balance_of(self, node: str) -> float:
        return self.view.balance.get(node, 0.0)

    def stake_of(self, node: str) -> float:
        return self.view.stake.get(node, 0.0)

    def stakes(self) -> Dict[str, float]:
        return dict(self.view.stake)
