"""Gossip convergence, PoS sampling statistics, duel-and-judge behaviour."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.duel import DuelParams, expected_extra_requests, run_duel
from repro.core.gossip import PeerView, gossip_round, rounds_to_convergence
from repro.core.pos import pos_sample, pos_sample_one, selection_probs


class TestGossip:
    def test_pairwise_merge_reconciles(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        a.heartbeat(1.0)
        b.set_addr("tcp://b2", 1.0)
        gossip_round(a, b)
        assert a.records["b"].addr == "tcp://b2"
        assert b.records["a"].version == a.records["a"].version

    def test_offline_then_revive_wins_by_version(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        gossip_round(a, b)
        a.set_offline(2.0)
        gossip_round(a, b)
        assert not b.records["a"].online
        a.go = None
        a.heartbeat(3.0)       # revive bumps version again
        gossip_round(a, b)
        assert b.records["a"].online

    def test_failure_suspicion_is_local_not_viral(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        c = PeerView("c", "tcp://c")
        for v in (a, b, c):
            for w in (a, b, c):
                if v is not w:
                    gossip_round(v, w)
        # b stops heartbeating; a suspects after timeout
        a.suspect_failures(100.0, suspect_after=5.0)
        assert not a.records["b"].online
        # ... but a live b's next heartbeat re-wins on merge
        b.heartbeat(101.0)
        gossip_round(a, b)
        assert a.records["b"].online

    @given(st.integers(3, 12), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_convergence_within_log_rounds(self, n, seed):
        rng = np.random.default_rng(seed)
        views = [PeerView(f"n{i}", f"tcp://n{i}") for i in range(n)]
        # bootstrap: ring introduction
        for i in range(n):
            gossip_round(views[i], views[(i + 1) % n])
        for v in views:
            v.heartbeat(1.0)
        rounds = rounds_to_convergence(views, rng, fanout=2)
        assert rounds <= 2 * int(np.ceil(np.log2(n))) + 3


class TestPoS:
    def test_probs_proportional_to_stake(self):
        stakes = {"a": 1.0, "b": 3.0, "c": 6.0}
        p = selection_probs(stakes, ["a", "b", "c"])
        assert p["c"] == pytest.approx(0.6)
        assert p["b"] == pytest.approx(0.3)

    def test_zero_stake_uniform_fallback(self):
        p = selection_probs({}, ["a", "b"])
        assert p["a"] == pytest.approx(0.5)

    def test_empirical_selection_frequency(self):
        rng = np.random.default_rng(0)
        stakes = {"a": 1.0, "b": 2.0, "c": 4.0}
        counts = {"a": 0, "b": 0, "c": 0}
        for _ in range(4000):
            counts[pos_sample_one(stakes, list(stakes), rng)] += 1
        assert counts["c"] / 4000 == pytest.approx(4 / 7, abs=0.03)
        assert counts["b"] / 4000 == pytest.approx(2 / 7, abs=0.03)

    @given(st.integers(1, 5), st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_sample_without_replacement(self, k, seed):
        rng = np.random.default_rng(seed)
        stakes = {f"n{i}": float(i + 1) for i in range(6)}
        got = pos_sample(stakes, list(stakes), k, rng, exclude=["n0"])
        assert len(got) == k
        assert len(set(got)) == k
        assert "n0" not in got


class TestDuel:
    def test_outcome_credit_flow(self):
        rng = np.random.default_rng(0)
        params = DuelParams(r_add=2.0, penalty=1.5, judge_fee=0.25)
        out = run_duel("d0", "hi", "lo", ["j1", "j2"],
                       {"hi": 0.95, "lo": 0.05}, params, rng)
        kinds = [op.kind for op in out.ops]
        assert kinds.count("transfer") == 3       # winner + 2 judges
        assert kinds.count("slash") == 1
        total_minted = sum(op.amount for op in out.ops
                           if op.kind == "transfer")
        assert total_minted == pytest.approx(2.0 + 2 * 0.25)

    def test_quality_wins_statistically(self):
        rng = np.random.default_rng(1)
        params = DuelParams(judge_accuracy=0.9)
        wins = sum(run_duel(f"d{i}", "hi", "lo", ["j1", "j2", "j3"],
                            {"hi": 0.8, "lo": 0.3}, params, rng).winner == "hi"
                   for i in range(500))
        # P(hi true-wins) = 0.75; judges 90% accurate majority-of-3
        assert 0.6 < wins / 500 < 0.9

    def test_overhead_formula(self):
        assert expected_extra_requests(1000, 0.5, 0.1, 2) == pytest.approx(150)
