"""Token sampling: greedy / temperature / top-p (nucleus)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(key: jax.Array, logits: jax.Array,
           temperature: float | jax.Array = 0.0,
           top_p: float = 1.0, vocab_size: int | None = None) -> jax.Array:
    """logits: (B, 1, V) -> tokens (B, 1) int32.

    ``temperature`` may be a scalar (whole batch) or a (B,) vector — batched
    serving mixes requests with different temperatures, and rows with
    temperature <= 0 decode greedily.
    """
    logits = logits[:, -1].astype(jnp.float32)
    if vocab_size is not None and vocab_size < logits.shape[-1]:
        # mask padded vocab entries
        pad_mask = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(pad_mask[None], -1e30, logits)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    if isinstance(temperature, (int, float)):
        if temperature <= 0.0:
            return greedy
        temperature = jnp.full((logits.shape[0],), temperature, jnp.float32)
    temperature = jnp.asarray(temperature, jnp.float32).reshape(-1)
    is_greedy = temperature <= 0.0
    logits = logits / jnp.where(is_greedy, 1.0, temperature)[:, None]
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    drawn = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)[:, None]
    return jnp.where(is_greedy[:, None], greedy, drawn)
