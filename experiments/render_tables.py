"""Render EXPERIMENTS.md roofline/dry-run tables from the dryrun JSONL logs.

    PYTHONPATH=src python experiments/render_tables.py
"""

import json
import sys
from pathlib import Path

HERE = Path(__file__).parent


def load(path):
    rows = {}
    if not (HERE / path).exists():
        return rows
    for line in open(HERE / path):
        r = json.loads(line)
        if "error" in r:
            continue
        rows[(r["arch"], r["shape"], r.get("perf_variant", "baseline"))] = r
    return rows


def roofline_table(rows):
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | peak GB/dev |",
           "|---|---|---:|---:|---:|---|---:|---:|"]
    for (a, s, _), r in sorted(rows.items()):
        rf = r.get("roofline")
        if not rf:
            continue
        out.append(
            f"| {a} | {s} | {rf['compute_ms']/1e3:.3f} | "
            f"{rf['memory_ms']/1e3:.2f} | {rf['collective_ms']/1e3:.2f} | "
            f"{rf['dominant']} | {rf['useful_fraction']:.2f} | "
            f"{r['memory']['peak_bytes']/1e9:.1f} |")
    return "\n".join(out)


def multipod_table(rows):
    out = ["| arch | shape | compile s | peak GB/dev |",
           "|---|---|---:|---:|"]
    for (a, s, _), r in sorted(rows.items()):
        out.append(f"| {a} | {s} | {r['compile_s']:.1f} | "
                   f"{r['memory']['peak_bytes']/1e9:.1f} |")
    return "\n".join(out)


def perf_table(rows):
    out = ["| arch | shape | variant | compute s | memory s | collective s "
           "| peak GB/dev |",
           "|---|---|---|---:|---:|---:|---:|"]
    for (a, s, v), r in rows.items():      # keep insertion (iteration) order
        rf = r.get("roofline", {})
        out.append(
            f"| {a} | {s} | {v} | {rf.get('compute_ms', 0)/1e3:.3f} | "
            f"{rf.get('memory_ms', 0)/1e3:.2f} | "
            f"{rf.get('collective_ms', 0)/1e3:.2f} | "
            f"{r['memory']['peak_bytes']/1e9:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    single = load("dryrun_single.jsonl")
    multi = load("dryrun_multipod.jsonl")
    perf = load("perf_iters.jsonl")
    print("## Single-pod (16x16) baselines\n")
    print(roofline_table(single))
    print(f"\n{len(single)} combinations compiled.\n")
    print("## Multi-pod (2x16x16)\n")
    print(multipod_table(multi))
    print(f"\n{len(multi)} combinations compiled.\n")
    print("## Perf iterations\n")
    print(perf_table(perf))
