from repro.sim.events import EventLoop
from repro.sim.executor import (DisaggTokenBucketExecutor, Executor,
                                ExecutorLoad, SpecTokenBucketExecutor,
                                TokenBucketExecutor)
from repro.sim.metrics import CompletedRequest, MetricsCollector
from repro.sim.servicemodel import BackendProfile, make_profile
from repro.sim.workload import (ArrivalPhase, Request, WorkloadSpec,
                                make_requests, two_phase, uniform_phases)

__all__ = [
    "EventLoop", "Executor", "ExecutorLoad", "TokenBucketExecutor",
    "SpecTokenBucketExecutor", "DisaggTokenBucketExecutor",
    "CompletedRequest", "MetricsCollector",
    "BackendProfile", "make_profile", "ArrivalPhase", "Request",
    "WorkloadSpec", "make_requests", "two_phase", "uniform_phases",
]
