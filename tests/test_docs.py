"""check_docs: documentation cross-references stay resolvable in tier-1.

Code and the planning docs cite DESIGN.md sections by anchor (``§6.1``,
``§6.1-disagg``, ...).  Renaming or deleting a section must fail loudly
here instead of leaving dangling references in ROADMAP.md / CHANGES.md /
README.md — the executor layer is meant to be learnable from the docs
without reading PR history.

The anchor extraction and resolution logic lives in
``repro.analysis.docanchors`` (DESIGN.md §7); these tests are thin
wrappers keeping the historical names, plus unit checks on the shared
``ANCHOR`` regex itself.  The generalized checker also validates
DESIGN.md-attributed anchors inside Python docstrings, which the old
markdown-only test never saw.
"""

import pathlib

from repro.analysis import run_analysis
from repro.analysis.docanchors import ANCHOR, REQUIRED_ANCHORS

REPO = pathlib.Path(__file__).resolve().parents[1]


def _docs_findings():
    report = run_analysis(REPO, rules=["docs-anchors"], baseline_path="")
    return [f.format() for f in report.new]


class TestCheckDocs:
    def test_design_defines_the_cited_sections(self):
        for a in ("§6.1", "§6.1-paged", "§6.1-disagg", "§6.1-spec", "§6.2",
                  "§6.3", "§7", "§Arch-applicability"):
            assert a in REQUIRED_ANCHORS, f"{a} dropped from the pinned set"
        missing = [f for f in _docs_findings() if "/required]" in f]
        assert not missing, "DESIGN.md lost a pinned heading:\n  " + \
            "\n  ".join(missing)

    def test_no_dangling_anchor_references(self):
        dangling = _docs_findings()
        assert not dangling, (
            "dangling DESIGN.md anchor references (rename the section back "
            "or update the referrer):\n  " + "\n  ".join(dangling))

    def test_anchor_regex_strips_trailing_punctuation(self):
        assert ANCHOR.findall("see §6.1-paged): and §6.2, then §6.1.") == \
            ["§6.1-paged", "§6.2", "§6.1"]


class TestReadme:
    """Acceptance: the root README exists and teaches the entry points."""

    def test_readme_covers_the_entry_points(self):
        text = (REPO / "README.md").read_text()
        for needle in ("python -m pytest", "--smoke", "--bench",
                       "pytest -m slow", "DESIGN.md"):
            assert needle in text, f"README.md does not mention {needle!r}"

    def test_readme_maps_the_architecture(self):
        text = (REPO / "README.md").read_text()
        for pkg in ("repro/core", "repro/sim", "repro/serving",
                    "repro/kernels", "repro/compat"):
            assert pkg in text, f"README.md architecture map misses {pkg}"
