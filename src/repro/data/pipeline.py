"""Synthetic, deterministic, shardable token pipeline.

Generates a mixture of (a) Zipf-distributed "natural" tokens and (b) embedded
copy patterns so that a small model trained a few hundred steps measurably
reduces loss (the quickstart train example asserts this).  Batches are plain
numpy on host; the caller places them on device / across the mesh.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_fraction: float = 0.3   # fraction of positions covered by copy spans
    copy_span: int = 16


class TokenPipeline:
    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic per-step batch: {"tokens", "labels"}."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        zipf = rng.zipf(cfg.zipf_a, size=(b, s + 1))
        toks = (zipf % (cfg.vocab_size - 2)) + 2      # 0/1 reserved
        # overlay copy spans: x[t .. t+span] = x[t-span .. t]
        n_spans = int(cfg.copy_fraction * s / cfg.copy_span)
        for i in range(b):
            starts = rng.integers(cfg.copy_span, s - cfg.copy_span,
                                  size=n_spans)
            for t in starts:
                toks[i, t:t + cfg.copy_span] = toks[i, t - cfg.copy_span:t]
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
