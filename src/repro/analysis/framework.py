"""Static-analysis framework: findings, suppressions, baseline, registry.

The repo's architectural contracts (DESIGN.md §7) — the compat boundary,
the layering DAG, kernel hygiene, sim/engine twin agreement, and doc
anchors — used to be defended by string greps scattered across the test
suite.  This package replaces them with one AST-based analyzer:

* ``Finding(rule_id, path, line, msg)`` — one structured violation.
* ``RepoIndex`` — the shared view of the repository every checker reads:
  file listing per scan dir, text/line access, and a **per-file parse
  cache** so five checkers parsing the same tree cost one ``ast.parse``.
* ``Checker`` + ``register`` — the checker registry.  A checker owns one
  top-level rule id (``compat-boundary``, ``layering``, ...) and may emit
  findings under sub-rule ids (``layering/import-dag``); suppressions and
  rule selection match either the full id or the top-level prefix.
* inline suppressions — ``# repro: allow[rule-id]`` on the offending line
  (or on a comment line directly above it) waives that rule there.  Used
  for *intentional* exceptions with a one-line justification; accidental
  regressions have no comment and fail.
* baseline — a committed JSON file (``analysis_baseline.json`` at the repo
  root) of grandfathered findings, matched by ``(rule, path, msg)`` (no
  line numbers, so unrelated edits don't churn it).  New violations fail
  while baselined ones are only tracked.  The goal state — enforced by
  ``tests/test_analysis.py`` — is an *empty* baseline.

Stdlib-only (``ast``): no new dependencies.
"""

from __future__ import annotations

import ast
import json
import pathlib
import re
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# directories scanned relative to the repo root (missing ones are skipped)
SCAN_DIRS = ("src", "tests", "benchmarks")

# committed baseline of grandfathered findings, repo-root relative
BASELINE_FILE = "analysis_baseline.json"

# inline suppression: "# repro: allow[rule-a, rule-b/sub]"
_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_\-/,\s]+)\]")


@dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a specific source location."""

    rule_id: str
    path: str          # repo-root-relative posix path
    line: int          # 1-based; 0 for whole-file findings
    msg: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule_id}] {self.msg}"

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: line numbers shift on unrelated edits, so
        grandfathered findings are matched by (rule, path, msg)."""
        return (self.rule_id, self.path, self.msg)


def rule_matches(selector: str, rule_id: str) -> bool:
    """``selector`` selects ``rule_id`` exactly or as its top-level prefix
    (``layering`` matches ``layering/import-dag``)."""
    return rule_id == selector or rule_id.startswith(selector + "/")


class RepoIndex:
    """Read-only repository view shared by all checkers in one run.

    Texts, line splits, parsed ASTs, and suppression tables are cached per
    file, so the cost of N checkers is one parse per file plus N
    traversals.  Files that fail to parse are reported once through
    ``parse_errors`` (the runner turns them into findings) and excluded
    from ``tree``-based analysis.
    """

    def __init__(self, root, scan_dirs: Sequence[str] = SCAN_DIRS) -> None:
        self.root = pathlib.Path(root).resolve()
        self.scan_dirs = tuple(d for d in scan_dirs
                               if (self.root / d).is_dir())
        self.parse_errors: Dict[str, str] = {}
        self._py_files: Optional[List[str]] = None
        self._text: Dict[str, str] = {}
        self._lines: Dict[str, List[str]] = {}
        self._tree: Dict[str, Optional[ast.Module]] = {}
        self._suppress: Dict[str, Dict[int, Set[str]]] = {}

    # ------------------------------------------------------------------ files
    def py_files(self) -> List[str]:
        """Sorted repo-relative paths of every Python file in scope."""
        if self._py_files is None:
            out: List[str] = []
            for d in self.scan_dirs:
                out.extend(p.relative_to(self.root).as_posix()
                           for p in (self.root / d).rglob("*.py"))
            self._py_files = sorted(out)
        return list(self._py_files)

    def exists(self, rel: str) -> bool:
        return (self.root / rel).is_file()

    def text(self, rel: str) -> str:
        if rel not in self._text:
            self._text[rel] = (self.root / rel).read_text()
        return self._text[rel]

    def lines(self, rel: str) -> List[str]:
        if rel not in self._lines:
            self._lines[rel] = self.text(rel).splitlines()
        return self._lines[rel]

    def tree(self, rel: str) -> Optional[ast.Module]:
        """Parsed AST for ``rel`` (cached), or None on syntax error."""
        if rel not in self._tree:
            try:
                self._tree[rel] = ast.parse(self.text(rel), filename=rel)
            except SyntaxError as e:
                self._tree[rel] = None
                self.parse_errors[rel] = f"line {e.lineno}: {e.msg}"
        return self._tree[rel]

    def module_name(self, rel: str) -> Optional[str]:
        """Importable dotted name for ``rel`` (``src/repro/sim/x.py`` ->
        ``repro.sim.x``), or None for non-importable layouts."""
        parts = pathlib.PurePosixPath(rel).with_suffix("").parts
        if parts and parts[0] == "src":
            parts = parts[1:]
        if not parts:
            return None
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts) if parts else None

    # ---------------------------------------------------------- suppressions
    def suppressions(self, rel: str) -> Dict[int, Set[str]]:
        """line -> allowed rule selectors.  A comment-only allow line also
        covers the next line, so long statements can carry a justification
        comment above them."""
        if rel not in self._suppress:
            table: Dict[int, Set[str]] = {}
            for i, line in enumerate(self.lines(rel), 1):
                m = _ALLOW_RE.search(line)
                if not m:
                    continue
                rules = {tok.strip() for tok in m.group(1).split(",")
                         if tok.strip()}
                table.setdefault(i, set()).update(rules)
                if line.lstrip().startswith("#"):      # comment-only line
                    table.setdefault(i + 1, set()).update(rules)
            self._suppress[rel] = table
        return self._suppress[rel]

    def is_suppressed(self, f: Finding) -> bool:
        if not f.path.endswith(".py"):
            return False
        table = self.suppressions(f.path)
        return any(rule_matches(sel, f.rule_id)
                   for sel in table.get(f.line, ()))


class Checker:
    """One registered rule family.  Subclasses set ``rule_id`` and
    ``description`` and yield ``Finding``s from ``run``; sub-rules use ids
    of the form ``<rule_id>/<sub>``."""

    rule_id: str = ""
    description: str = ""

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        raise NotImplementedError


_REGISTRY: Dict[str, Checker] = {}


def register(cls):
    """Class decorator: instantiate and add to the checker registry."""
    inst = cls()
    if not inst.rule_id:
        raise ValueError(f"{cls.__name__} has no rule_id")
    if inst.rule_id in _REGISTRY:
        raise ValueError(f"duplicate checker rule_id {inst.rule_id!r}")
    _REGISTRY[inst.rule_id] = inst
    return cls


def all_checkers() -> List[Checker]:
    # the checker modules self-register on package import (repro.analysis
    # imports them); sorting keeps output deterministic
    return [(_REGISTRY[k]) for k in sorted(_REGISTRY)]


# ------------------------------------------------------------------ baseline
def load_baseline(path) -> List[Tuple[str, str, str]]:
    """Baseline entries as (rule, path, msg) keys; missing file = empty."""
    p = pathlib.Path(path)
    if not p.is_file():
        return []
    payload = json.loads(p.read_text())
    return [(e["rule"], e["path"], e["msg"])
            for e in payload.get("entries", [])]


def save_baseline(path, findings: Sequence[Finding]) -> None:
    payload = {
        "comment": "grandfathered analysis findings; see DESIGN.md §7 — "
                   "the goal state is an empty list",
        "entries": [{"rule": f.rule_id, "path": f.path, "msg": f.msg}
                    for f in sorted(findings)],
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")


# -------------------------------------------------------------------- runner
@dataclass
class Report:
    """Outcome of one analysis pass over a repository."""

    new: List[Finding] = field(default_factory=list)        # fail the run
    suppressed: List[Finding] = field(default_factory=list)  # inline allows
    baselined: List[Finding] = field(default_factory=list)   # grandfathered
    rules: List[str] = field(default_factory=list)           # checkers run
    wall_s: float = 0.0

    @property
    def all_findings(self) -> List[Finding]:
        return sorted(self.new + self.suppressed + self.baselined)

    @property
    def ok(self) -> bool:
        return not self.new


def run_analysis(root, rules: Optional[Sequence[str]] = None,
                 baseline_path=None,
                 scan_dirs: Sequence[str] = SCAN_DIRS) -> Report:
    """Run the registered checkers over the repo at ``root``.

    ``rules`` selects checkers by top-level id (None = all).
    ``baseline_path``: None = ``<root>/analysis_baseline.json`` when it
    exists; pass an explicit path to force one, or "" to disable.
    """
    t0 = time.perf_counter()
    repo = RepoIndex(root, scan_dirs)
    checkers = [c for c in all_checkers()
                if rules is None
                or any(rule_matches(sel, c.rule_id)
                       or c.rule_id.startswith(sel) for sel in rules)]
    raw: List[Finding] = []
    for checker in checkers:
        raw.extend(checker.run(repo))
    for rel, err in sorted(repo.parse_errors.items()):
        raw.append(Finding("parse-error", rel, 0, err))

    if baseline_path is None:
        baseline_path = repo.root / BASELINE_FILE
    baseline = list(load_baseline(baseline_path)) if baseline_path else []

    report = Report(rules=[c.rule_id for c in checkers])
    for f in sorted(set(raw)):
        if repo.is_suppressed(f):
            report.suppressed.append(f)
        elif f.key() in baseline:
            baseline.remove(f.key())       # multiset semantics
            report.baselined.append(f)
        else:
            report.new.append(f)
    report.wall_s = time.perf_counter() - t0
    return report
