"""Benchmark harness: one entry per paper table/figure + substrate benches.

Prints ``name,us_per_call,derived`` CSV (one row per artifact).  Roofline
numbers come from ``repro.launch.dryrun`` (see EXPERIMENTS.md §Roofline) —
that path needs 512 host devices and therefore runs as its own process.

``--smoke`` instead runs a <60s end-to-end sanity pass (model forward,
prefill/decode consistency, real engine generation, Pallas kernel vs
oracle, mesh-context sharding) so regressions in the tier-1 command are
caught before a full pytest run::

    PYTHONPATH=src python benchmarks/run.py --smoke

``--bench`` emits a machine-readable ``BENCH_scheduling.json`` (SLO
attainment per mode, avg/p95 latency, simulated requests/s, real-engine
decode tokens/s and admitted concurrency for paged vs slot vs wave
batching, the disagg-vs-colocated TTFT mix, the speculative-vs-paged
decode-heavy comparison with its accepted-length distribution, the pinned
kernel microbench — slot vs paged vs quantized-paged decode/spec-verify
timings at fixed shapes, the autotuned ``pages_per_step``, and the int8
admission 2x demo — the schema-7 ``gossip`` scale-out section:
gossip-digest vs power-of-two probe routing at 100 and 1k sim nodes with
SLO attainment and routing messages-per-request, whose >=3x message cut
at matched SLO is asserted by ``check_bench_schema`` — and, new in
schema 8, the ``prefix_cache`` section (DESIGN.md §6.1-prefix): real-
engine cached-vs-cold TTFT on a shared prefix (cached must be faster),
the simulated zipf-shared-prefix hit rate (>= 0.5), and cache-affinity
vs affinity-blind gossip routing on a hot-origin zipf workload
(affinity must win on aggregate hit rate), and, new in schema 9, the
``obs`` tracing-overhead section (DESIGN.md §Observability): mix-bench
decode tokens/s with the span tracer enabled vs disabled, whose
>= 0.95x ratio is asserted by ``check_bench_schema``) so the
performance trajectory is tracked PR over PR::

    PYTHONPATH=src python benchmarks/run.py --bench

The payload shape is pinned by ``check_bench_schema`` (validated here at
write time and against the checked-in file by ``tests/test_compat.py``, so
schema drift is caught in tier-1).

``--lint`` runs the AST invariant linter (``repro.analysis``,
DESIGN.md §7) over src/tests/benchmarks — a <10s jax-free pass that is
also the first check of ``--smoke`` and whose rule/violation counts are
recorded in the ``lint`` section of the --bench payload::

    PYTHONPATH=src python benchmarks/run.py --lint

``--trace <path>`` runs the traced sim mix (DESIGN.md §Observability):
a small decentralized network with the span tracer live, writing a
Perfetto/Chrome ``trace_event`` JSON to <path> and printing the
per-request latency breakdown.  It asserts the latency partition: for
every completed request, the union of its merged sim-clock span
intervals (route.decide / executor.queue / engine.prefill /
engine.decode / route.return, plus the nested disagg.handoff) must
reconstruct ``CompletedRequest.latency`` within 5%::

    PYTHONPATH=src python benchmarks/run.py --trace out.json
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time
from typing import List

# allow `python benchmarks/run.py` without the repo root on PYTHONPATH
# (the sibling benchmark modules import as the ``benchmarks`` package,
# and repro imports from src/)
_REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_REPO))
sys.path.insert(0, str(_REPO / "src"))

BENCH_SCHEMA_VERSION = 9

# required keys per payload section; engine modes each carry ENGINE_MODE_KEYS
SIM_MODE_KEYS = ("slo_attainment", "avg_latency_s", "p95_latency_s",
                 "delegation_rate", "n")
ENGINE_MODE_KEYS = ("decode_tokens", "decode_steps", "decode_tokens_per_s",
                    "wall_s", "admitted_concurrency", "max_batch",
                    "kv_budget_tokens")
ENGINE_MODES = ("slot", "wave", "paged")
# schema 3: mixed prompt-heavy/decode-heavy workload, disagg vs colocated
# (DESIGN.md §6.1-disagg) — TTFT per request class and decode throughput
MIX_MODES = ("slot", "paged", "disagg")
MIX_MODE_KEYS = ("avg_ttft_prompt_heavy_s", "avg_ttft_decode_heavy_s",
                 "decode_tokens_per_s", "wall_s", "served")
# schema 4: decode-heavy workload, speculative vs plain paged (DESIGN.md
# §6.1-spec) — accepted-length distribution and effective decode tokens/s
SPEC_MODES = ("paged", "spec")
SPEC_MODE_KEYS = ("decode_tokens", "decode_tokens_per_s", "wall_s", "served")
SPEC_ONLY_KEYS = ("accept_hist", "alpha_ema", "expected_tokens_per_step",
                  "draft_wall_s", "verify_steps")
# schema 5: static-analysis snapshot (DESIGN.md §7) — which rules ran and
# the violation counts by disposition, so a silently growing baseline or
# suppression set shows up in the PR-over-PR artifact diff
LINT_KEYS = ("rules", "new", "suppressed", "baselined", "wall_s")
# schema 6: pinned kernel microbench (DESIGN.md §Perf-kernels) — paged vs
# slot vs quantized-paged decode and spec-verify timings at fixed shapes,
# the pages_per_step the autotune sweep recorded, and the int8 admission
# demo (same page budget, fp vs kv_quant engine) whose 2x is asserted here
KERNEL_DECODE_MODES = ("slot", "paged", "paged_quant")
KERNEL_VERIFY_MODES = ("paged", "paged_quant")
KERNEL_TUNING_KEYS = ("page_size", "head_dim", "hkv", "pages_per_step")
KERNEL_ADMISSION_KEYS = ("num_pages", "page_size", "paged", "paged_quant")
# schema 7: gossip load-dissemination scale-out (DESIGN.md §6.2-gossip) —
# gossip-digest vs power-of-two probe routing at 100 and 1k sim nodes;
# the 10k point stays out of tier-1 behind `-m slow` (tests/test_scaling.py)
GOSSIP_POINTS = ("100", "1000")
GOSSIP_ROUTING_MODES = ("gossip", "probe")
GOSSIP_MODE_KEYS = ("slo_attainment", "p95_latency_s",
                    "routing_msgs_per_req", "gossip_msgs", "probes",
                    "dispatches", "bounces", "delegation_rate", "n",
                    "wall_s")
# schema 8: cross-request prefix caching (DESIGN.md §6.1-prefix) — real
# engine cached-vs-cold TTFT on a shared prefix, the simulated
# zipf-shared-prefix hit rate, and cache-affinity vs affinity-blind
# gossip dispatch on a hot-origin zipf workload
PREFIX_ENGINE_KEYS = ("cold_ttft_s", "cached_ttft_s", "ttft_speedup",
                      "hit_tokens", "cached_pages", "prefix_tokens",
                      "suffix_tokens")
PREFIX_SIM_KEYS = ("hit_rate", "hit_tokens", "lookup_tokens", "served")
PREFIX_ROUTING_MODES = ("affinity", "blind")
PREFIX_ROUTING_KEYS = ("hit_rate", "hit_tokens", "lookup_tokens", "n")
# schema 9: tracing overhead (DESIGN.md §Observability) — mix-workload
# paged decode throughput with the span tracer enabled vs disabled;
# check_bench_schema hard-asserts traced >= 0.95x untraced
OBS_ARMS = ("untraced", "traced")
OBS_ARM_KEYS = ("decode_tokens", "decode_tokens_per_s", "wall_s")
OBS_KEYS = ("workload", "overhead_ratio", "spans", "metrics")


def check_bench_schema(payload: dict) -> None:
    """Raise AssertionError when ``payload`` drifts from the pinned shape."""
    assert payload.get("schema") == BENCH_SCHEMA_VERSION, (
        f"schema {payload.get('schema')} != {BENCH_SCHEMA_VERSION}")
    assert payload.get("bench") == "scheduling"
    sim = payload["sim"]
    for k in ("setting", "wall_s", "requests_per_s", "modes"):
        assert k in sim, f"sim.{k} missing"
    for mode in ("single", "centralized", "decentralized"):
        for k in SIM_MODE_KEYS:
            assert k in sim["modes"][mode], f"sim.modes.{mode}.{k} missing"
    eng = payload["engine"]
    assert "model" in eng, "engine.model missing"
    for mode in ENGINE_MODES:
        assert mode in eng, f"engine.{mode} missing"
        for k in ENGINE_MODE_KEYS:
            assert k in eng[mode], f"engine.{mode}.{k} missing"
    for k in ("page_size", "num_pages", "preempted"):
        assert k in eng["paged"], f"engine.paged.{k} missing"
    mix = payload["mix"]
    for k in ("workload", "ttft_speedup_prompt_heavy"):
        assert k in mix, f"mix.{k} missing"
    for mode in MIX_MODES:
        assert mode in mix, f"mix.{mode} missing"
        for k in MIX_MODE_KEYS:
            assert k in mix[mode], f"mix.{mode}.{k} missing"
    for k in ("handoffs", "handoff_bytes", "transfer_inflight_peak"):
        assert k in mix["disagg"], f"mix.disagg.{k} missing"
    # schema 6 perf bar: the tuned paged engine (carry-borne pools, donated
    # buffers, device-resident width-trimmed tables — DESIGN.md
    # §Perf-kernels) must not decode slower than slot batching on the mix
    assert (mix["paged"]["decode_tokens_per_s"]
            >= mix["slot"]["decode_tokens_per_s"]), (
        f"mix paged decode {mix['paged']['decode_tokens_per_s']} tok/s "
        f"regressed below slot {mix['slot']['decode_tokens_per_s']}")
    spec = payload["spec"]
    for k in ("workload", "spec_k", "speedup_decode_tokens_per_s"):
        assert k in spec, f"spec.{k} missing"
    for mode in SPEC_MODES:
        assert mode in spec, f"spec.{mode} missing"
        for k in SPEC_MODE_KEYS:
            assert k in spec[mode], f"spec.{mode}.{k} missing"
    for k in SPEC_ONLY_KEYS:
        assert k in spec["spec"], f"spec.spec.{k} missing"
    assert len(spec["spec"]["accept_hist"]) == spec["spec_k"] + 1
    lint = payload["lint"]
    for k in LINT_KEYS:
        assert k in lint, f"lint.{k} missing"
    assert lint["new"] == 0, "lint.new must be 0 in a committed artifact"
    kern = payload["kernel"]
    for k in ("shapes", "tuning", "decode", "spec_verify", "admission"):
        assert k in kern, f"kernel.{k} missing"
    for mode in KERNEL_DECODE_MODES:
        assert mode in kern["decode"], f"kernel.decode.{mode} missing"
        assert "us_per_call" in kern["decode"][mode], \
            f"kernel.decode.{mode}.us_per_call missing"
    for mode in KERNEL_VERIFY_MODES:
        assert mode in kern["spec_verify"], f"kernel.spec_verify.{mode} missing"
        assert "us_per_call" in kern["spec_verify"][mode], \
            f"kernel.spec_verify.{mode}.us_per_call missing"
    for k in KERNEL_TUNING_KEYS:
        assert k in kern["tuning"], f"kernel.tuning.{k} missing"
    adm = kern["admission"]
    for k in KERNEL_ADMISSION_KEYS:
        assert k in adm, f"kernel.admission.{k} missing"
    gos = payload["gossip"]
    for k in ("workload", "slo_s", "points"):
        assert k in gos, f"gossip.{k} missing"
    for pt in GOSSIP_POINTS:
        assert pt in gos["points"], f"gossip.points.{pt} missing"
        entry = gos["points"][pt]
        for k in ("msgs_ratio", "slo_gap"):
            assert k in entry, f"gossip.points.{pt}.{k} missing"
        for mode in GOSSIP_ROUTING_MODES:
            assert mode in entry, f"gossip.points.{pt}.{mode} missing"
            for k in GOSSIP_MODE_KEYS:
                assert k in entry[mode], \
                    f"gossip.points.{pt}.{mode}.{k} missing"
    # schema 7 scale-out bar (ROADMAP item 1 / DESIGN.md §6.2-gossip): at
    # 1k nodes the digest plane must cut routing messages-per-request at
    # least 3x while holding SLO attainment within 2 points of the
    # power-of-two probe baseline
    big = gos["points"]["1000"]
    assert (big["gossip"]["routing_msgs_per_req"]
            < big["probe"]["routing_msgs_per_req"]), (
        f"gossip routing msgs/req {big['gossip']['routing_msgs_per_req']} "
        f"not below probe {big['probe']['routing_msgs_per_req']} at 1k nodes")
    assert big["msgs_ratio"] >= 3.0, (
        f"gossip message cut {big['msgs_ratio']}x < 3x at 1k nodes")
    assert big["slo_gap"] <= 0.02, (
        f"gossip-vs-probe SLO gap {big['slo_gap']} > 0.02 at 1k nodes")
    # schema 6 capacity bar: int8 KV pages halve bytes per token, so on the
    # same page budget the kv_quant engine must keep at least twice the
    # concurrent residents of the fp paged engine (DESIGN.md §6.1-paged)
    assert adm["paged_quant"] >= 2 * adm["paged"], (
        f"quantized admission {adm['paged_quant']} < "
        f"2x fp admission {adm['paged']}")
    # schema 8: cross-request prefix caching (DESIGN.md §6.1-prefix)
    pc = payload["prefix_cache"]
    for k in ("workload", "engine", "sim", "routing"):
        assert k in pc, f"prefix_cache.{k} missing"
    for k in PREFIX_ENGINE_KEYS:
        assert k in pc["engine"], f"prefix_cache.engine.{k} missing"
    for k in PREFIX_SIM_KEYS:
        assert k in pc["sim"], f"prefix_cache.sim.{k} missing"
    for mode in PREFIX_ROUTING_MODES:
        assert mode in pc["routing"], f"prefix_cache.routing.{mode} missing"
        for k in PREFIX_ROUTING_KEYS:
            assert k in pc["routing"][mode], \
                f"prefix_cache.routing.{mode}.{k} missing"
    # hard bars: a prefix hit must serve its first token faster than the
    # cold prefill of the same prompt; the zipf workload must actually
    # exercise the cache; and cache-affinity dispatch must beat
    # affinity-blind gossip routing on aggregate hit rate
    assert pc["engine"]["cached_ttft_s"] < pc["engine"]["cold_ttft_s"], (
        f"cached TTFT {pc['engine']['cached_ttft_s']} not below cold "
        f"{pc['engine']['cold_ttft_s']}")
    assert pc["sim"]["hit_rate"] >= 0.5, (
        f"zipf-shared-prefix sim hit rate {pc['sim']['hit_rate']} < 0.5")
    assert (pc["routing"]["affinity"]["hit_rate"]
            > pc["routing"]["blind"]["hit_rate"]), (
        f"cache-affinity hit rate {pc['routing']['affinity']['hit_rate']} "
        f"not above blind {pc['routing']['blind']['hit_rate']}")
    # schema 9: tracing overhead (DESIGN.md §Observability)
    obs = payload["obs"]
    for k in OBS_KEYS:
        assert k in obs, f"obs.{k} missing"
    for arm in OBS_ARMS:
        assert arm in obs, f"obs.{arm} missing"
        for k in OBS_ARM_KEYS:
            assert k in obs[arm], f"obs.{arm}.{k} missing"
    assert obs["spans"] > 0, "traced arm recorded no spans"
    # hard bar: spans are cheap enough to leave on — traced mix decode
    # throughput must hold >= 0.95x of the untraced arm
    assert obs["overhead_ratio"] >= 0.95, (
        f"tracing overhead: traced decode "
        f"{obs['traced']['decode_tokens_per_s']} tok/s is "
        f"{obs['overhead_ratio']}x untraced "
        f"{obs['untraced']['decode_tokens_per_s']} (< 0.95x)")


def _lint(verbose: bool = True) -> int:
    """Run the AST invariant linter (DESIGN.md §7); jax-free and <10s."""
    from repro.analysis import run_analysis
    report = run_analysis(_REPO)
    if verbose:
        for f in report.new:
            print(f"  {f.format()}", flush=True)
        print(f"lint: {len(report.rules)} checkers, {len(report.new)} new "
              f"/ {len(report.suppressed)} suppressed / "
              f"{len(report.baselined)} baselined in {report.wall_s:.2f}s",
              flush=True)
    return 0 if report.ok else 1


def _traced_sim_mix(n_requests: int = 30, seed: int = 0):
    """Small decentralized sim mix with the span tracer live (jax-free).

    Duels, churn, and rebalancing are off, so each completed request's
    lifecycle spans — route.decide, executor.queue, engine.prefill,
    engine.decode, route.return (plus the nested disagg.handoff on the
    disagg node) — tile [arrival, finish] exactly (DESIGN.md
    §Observability).  Returns (metrics, tracer, network).
    """
    from repro.core import DuelParams, Network, Node, NodePolicy
    from repro.obs import Tracer, set_tracer
    from repro.sim import DisaggTokenBucketExecutor, make_profile
    from repro.sim.workload import Request
    net = Network(mode="decentralized", seed=seed,
                  duel=DuelParams(p_d=0.0, k_judges=0), init_balance=100.0)
    # offload-eager policy (low utilization knee) so the trace actually
    # carries delegation legs (route.decide dispatch spans + route.return)
    # rather than an everything-local run
    pol = NodePolicy(accept_freq=1.0, offload_freq=1.0,
                     offload_queue_threshold=0, offload_util_threshold=0.3)
    for i in range(4):
        # one disagg backend so traces carry disagg.handoff spans nested
        # inside engine.decode (exercises the merged-interval coverage)
        factory = ((lambda node: DisaggTokenBucketExecutor(node.profile))
                   if i == 3 else None)
        net.add_node(Node(f"n{i}",
                          make_profile("qwen3-8b", "RTX3090", "sglang",
                                       quality=0.5),
                          policy=pol, executor_factory=factory))
    reqs = []
    for i in range(n_requests):       # mixed prompt-heavy / decode-heavy,
        heavy = i % 3 == 0            # all hot on n0 so it must delegate
        reqs.append(Request(rid=f"t{i:03d}", origin="n0",
                            arrival=0.15 * i,
                            prompt_tokens=512 if heavy else 48,
                            output_tokens=16 if heavy else 96,
                            slo_s=120.0))
    tr = Tracer()
    old = set_tracer(tr)
    try:
        m = net.run(reqs, until=10_000.0, rebalance_interval=0.0)
    finally:
        set_tracer(old)
    return m, tr, net


def _span_coverage_errors(metrics, spans) -> dict:
    """Per-rid relative error of the span-reconstructed latency.

    For each completed request, merge its sim-clock span intervals and
    compare the union's length to ``CompletedRequest.latency`` — the
    lifecycle partition of DESIGN.md §Observability says they match
    (spans may nest, e.g. disagg.handoff inside engine.decode, so a
    plain sum over-counts; the merged union does not).
    """
    from repro.obs import SIM
    by = {}
    for s in spans:
        if s.rid and s.clock == SIM:
            by.setdefault(s.rid, []).append((s.t0, s.t1))
    errs = {}
    for c in metrics.completed:
        covered, hi = 0.0, None
        for t0, t1 in sorted(by.get(c.rid, ())):
            if hi is None or t0 > hi:
                covered += t1 - t0
                hi = t1
            elif t1 > hi:
                covered += t1 - hi
                hi = t1
        errs[c.rid] = abs(covered - c.latency) / max(c.latency, 1e-9)
    return errs


def _trace(out_path: str) -> int:
    """Write a Perfetto trace of the sim mix; assert the latency partition."""
    from repro.obs import breakdown_report, write_chrome_trace
    t0 = time.perf_counter()
    m, tr, _net = _traced_sim_mix()
    payload = write_chrome_trace(tr.spans, out_path)
    print(breakdown_report(tr.spans, limit=5))
    errs = _span_coverage_errors(m, tr.spans)
    worst = max(errs.values()) if errs else 1.0
    print(f"trace: {len(m.completed)} requests, {len(tr.spans)} spans, "
          f"{len(payload['traceEvents'])} events -> {out_path} "
          f"({time.perf_counter() - t0:.1f}s)")
    print(f"trace: worst span-coverage error {worst:.4f} "
          f"(merged sim spans vs CompletedRequest.latency)")
    assert worst <= 0.05, (
        f"span partition broken: worst relative coverage error {worst:.4f} "
        f"> 0.05 (DESIGN.md §Observability)")
    return 0


def _smoke() -> int:
    """End-to-end sanity: fail fast and loudly, return a shell exit code."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    t_start = time.perf_counter()
    failures: List[str] = []

    def check(name, fn):
        t0 = time.perf_counter()
        try:
            fn()
            print(f"  ok   {name} ({time.perf_counter() - t0:.1f}s)",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — collect, report all
            failures.append(name)
            print(f"  FAIL {name}: {e!r}", flush=True)

    def model_roundtrip():
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                  cfg.vocab_size)
        fam = registry.get_family(cfg)
        lg, cache = fam.prefill(params, cfg, {"tokens": toks}, q_chunk=32,
                                kv_chunk=32, capacity=48)
        assert not bool(jnp.isnan(lg).any())
        nt = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, _ = fam.decode_step(params, cfg, cache, nt)
        full = jnp.concatenate([toks, nt], axis=1)
        ref = registry.apply_logits(params, cfg, {"tokens": full},
                                    q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(lg2),
                                   np.asarray(ref[:, -1:]),
                                   atol=2e-4, rtol=2e-3)

    def engine_generates():
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, max_batch=2, bucket=16)
        prompt = np.arange(2, 14).astype(np.int32)
        done = eng.serve([GenRequest(rid="a", tokens=prompt, max_new=4),
                          GenRequest(rid="b", tokens=prompt, max_new=2,
                                     temperature=1.0)])
        assert len(done[0].result) <= 4 and len(done[1].result) <= 2

    def paged_engine_matches_slot():
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)

        def mk():
            prompts = [np.random.default_rng(i).integers(2, 400, size=8 + 4 * i)
                       .astype(np.int32) for i in range(3)]
            return [GenRequest(rid=f"r{i}", tokens=prompts[i],
                               max_new=[4, 10, 4][i]) for i in range(3)]

        slot = Engine(cfg, params, max_batch=2, bucket=16)
        paged = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                       page_size=16, num_pages=5)   # tight: preempts
        rs, rp = slot.serve(mk()), paged.serve(mk())
        for a, b in zip(rs, rp):
            np.testing.assert_array_equal(a.result, b.result)
        snap = paged.load_snapshot()
        assert snap["pages_used"] == 0 and snap["free_pages"] == 5

    def disagg_matches_colocated_paged():
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import DisaggEngineExecutor, Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)

        def mk():
            prompts = [np.random.default_rng(i).integers(2, 400, size=6 + 5 * i)
                       .astype(np.int32) for i in range(3)]
            return [GenRequest(rid=f"r{i}", tokens=prompts[i],
                               max_new=[6, 9, 4][i]) for i in range(3)]

        ref = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=16)
        rs = {r.rid: r.result for r in ref.serve(mk())}
        ex = DisaggEngineExecutor(
            Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                   page_size=16),
            Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                   page_size=16))
        ex.bind(None, lambda r, st, ft: None)
        for r in mk():
            assert ex.admit(r)
        done = {r.rid: r.result for r in ex.drain()}
        for rid in rs:
            np.testing.assert_array_equal(rs[rid], done[rid])
        assert ex.prefill.stats.handoffs == 3
        assert ex.prefill.load_snapshot()["pages_used"] == 0
        assert ex.decode.load_snapshot()["pages_used"] == 0

    def spec_engine_matches_paged():
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        draft_cfg = cfg.draft()
        draft_params = registry.init(jax.random.PRNGKey(9), draft_cfg)

        def mk():
            prompts = [np.random.default_rng(i).integers(2, 400, size=6 + 3 * i)
                       .astype(np.int32) for i in range(3)]
            return [GenRequest(rid=f"r{i}", tokens=prompts[i],
                               max_new=[6, 9, 4][i]) for i in range(3)]

        ref = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=16)
        rs = {r.rid: r.result for r in ref.serve(mk())}
        spec = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                      page_size=16, spec_draft=(draft_cfg, draft_params),
                      spec_k=3)
        rp = {r.rid: r.result for r in spec.serve(mk())}
        for rid in rs:
            np.testing.assert_array_equal(rs[rid], rp[rid])
        assert spec.stats.spec_steps > 0
        assert spec.load_snapshot()["pages_used"] == 0

    def prefix_cache_parity():
        # cached-vs-cold bit parity + hit-rate sanity (DESIGN.md
        # §6.1-prefix): serving the same shared prefix twice must produce
        # bit-identical greedy output to a cache-less engine while actually
        # hitting the cache, with the page pool reconciling exactly
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        prefix = np.random.default_rng(5).integers(2, 400, size=35) \
            .astype(np.int32)

        def mk(rid, sufseed):
            suf = np.random.default_rng(sufseed).integers(2, 400, size=7) \
                .astype(np.int32)
            return GenRequest(rid=rid,
                              tokens=np.concatenate([prefix, suf]),
                              max_new=4)

        cold = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                      page_size=16, num_pages=32)
        ref = {r.rid: np.asarray(r.result)
               for r in cold.serve([mk("a", 1), mk("b", 2)])}
        warm = Engine(cfg, params, max_batch=2, bucket=16, paged=True,
                      page_size=16, num_pages=32, prefix_cache=True)
        got = {}
        for rid, ss in (("a", 1), ("b", 2)):   # sequential: b hits a's pages
            got.update({r.rid: np.asarray(r.result)
                        for r in warm.serve([mk(rid, ss)])})
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])
        assert warm.prefix_hit_tokens > 0, "no prefix-cache hits"
        acct = warm.debug_page_accounting()
        assert acct["cold"] > 0 and acct["held"] == 0

    def pallas_kernel_matches_oracle():
        from repro.kernels.flash_attention import flash_attention_tpu
        from repro.kernels.ref import reference_attention
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        out = flash_attention_tpu(q, k, v, causal=True, block_q=64,
                                  block_k=64, interpret=True)
        ref = reference_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=1e-3)

    def mesh_context_sharding():
        from repro.compat import meshenv
        from repro.launch.mesh import make_host_mesh
        from repro.models import common as cm
        mesh = make_host_mesh()
        with meshenv.mesh_context(mesh):
            assert meshenv.axis_names() == ("data", "model")
            y = jax.jit(lambda a: cm.shard(a, "batch", "model"))(
                jnp.ones((2, 4)))
            assert y.shape == (2, 4)
        assert meshenv.current_mesh() is None

    def protocol_sim():
        from repro.core import DuelParams, Network, Node, NodePolicy
        from repro.sim import make_profile
        from repro.sim.workload import Request
        net = Network(mode="decentralized", seed=0,
                      duel=DuelParams(p_d=0.1, k_judges=1),
                      init_balance=100.0)
        for i in range(3):
            net.add_node(Node(f"n{i}", make_profile("qwen3-8b", "RTX3090",
                                                    "sglang", quality=0.5),
                              policy=NodePolicy()))
        reqs = [Request(rid=f"r{i}", origin="n0", arrival=0.05 * i,
                        prompt_tokens=16, output_tokens=8, slo_s=30.0)
                for i in range(20)]
        m = net.run(reqs, until=300.0)
        assert len(m.completed) >= 20

    def gossip_probe_parity():
        # fast scale-out parity (DESIGN.md §6.2-gossip): on a small pool the
        # digest plane must complete the same workload as probe routing
        # without spending more routing messages per request
        from benchmarks.scaling import run_scale_point
        point = dict(hot=2, hot_ia=1.0, bg_ia=16.0, t_end=15.0,
                     gossip_interval=1.0, view_cap=None)
        res = {r: run_scale_point(20, r, point=point)
               for r in ("gossip", "probe")}
        g, p = res["gossip"], res["probe"]
        assert g["n"] == g["n_submitted"], \
            f"gossip dropped requests: {g['n']}/{g['n_submitted']}"
        assert p["n"] == p["n_submitted"], \
            f"probe dropped requests: {p['n']}/{p['n_submitted']}"
        assert g["routing_msgs_per_req"] <= p["routing_msgs_per_req"], (
            f"gossip routing cost {g['routing_msgs_per_req']} msgs/req "
            f"above probe {p['routing_msgs_per_req']}")

    def analysis_clean():
        assert _lint(verbose=False) == 0, \
            "repro.analysis found new violations (run --lint for details)"

    def trace_roundtrip():
        # <10s jax-free trace round-trip (DESIGN.md §Observability): run a
        # small traced sim, write the Chrome trace to a temp file, and check
        # that the JSON parses, spans nest inside their request's lifetime,
        # every completed request carries the route->admit->prefill chain,
        # and the merged sim spans reconstruct its measured latency
        import json
        import tempfile

        from repro.obs import SIM, write_chrome_trace
        m, tr, _net = _traced_sim_mix(n_requests=12)
        assert m.completed, "traced sim completed nothing"
        with tempfile.TemporaryDirectory() as td:
            p = pathlib.Path(td) / "trace.json"
            write_chrome_trace(tr.spans, p)
            evs = json.loads(p.read_text())["traceEvents"]
        assert any(e["ph"] == "X" for e in evs), "no complete events"
        by_rid = {}
        for s in tr.spans:
            if s.rid:
                by_rid.setdefault(s.rid, []).append(s)
        errs = _span_coverage_errors(m, tr.spans)
        for c in m.completed:
            names = {s.name for s in by_rid.get(c.rid, ())}
            for need in ("route.decide", "executor.queue", "executor.admit",
                         "engine.prefill", "engine.decode"):
                assert need in names, f"{c.rid} missing {need} span"
            for s in by_rid[c.rid]:     # nesting: inside [arrival, finish]
                if s.clock == SIM:
                    assert (s.t0 >= c.arrival - 1e-9
                            and s.t1 <= c.finish + 1e-9), \
                        f"{c.rid} span {s.name} outside its lifecycle"
            assert errs[c.rid] <= 0.05, \
                f"{c.rid} span coverage error {errs[c.rid]:.4f} > 0.05"

    print("smoke: end-to-end sanity pass", flush=True)
    check("static analysis (repro.analysis)", analysis_clean)
    check("trace round-trip (spans nest, latency partition)",
          trace_roundtrip)
    check("model forward + prefill/decode consistency", model_roundtrip)
    check("serving engine generation", engine_generates)
    check("paged engine greedy-matches slot engine", paged_engine_matches_slot)
    check("disagg KV handoff greedy-matches colocated paged",
          disagg_matches_colocated_paged)
    check("speculative engine greedy-matches paged engine",
          spec_engine_matches_paged)
    check("prefix cache cached-vs-cold parity + hit rate",
          prefix_cache_parity)
    check("pallas flash kernel vs oracle (interpret)",
          pallas_kernel_matches_oracle)
    check("mesh context + sharding constraint", mesh_context_sharding)
    check("decentralized protocol sim", protocol_sim)
    check("gossip-vs-probe routing parity (20-node pool)",
          gossip_probe_parity)
    dt = time.perf_counter() - t_start
    if failures:
        print(f"smoke FAILED ({len(failures)}): {failures} in {dt:.1f}s",
              flush=True)
        return 1
    print(f"smoke OK in {dt:.1f}s", flush=True)
    return 0


def _bench(out_path: str) -> int:
    """Machine-readable perf snapshot: scheduling sim + real-engine decode."""
    import json

    import jax
    import numpy as np

    payload = {"schema": BENCH_SCHEMA_VERSION, "bench": "scheduling"}

    # --- simulated scheduling (paper Fig 4 / Table 2, setting1) -------------
    from benchmarks.scheduling import run_setting
    t0 = time.perf_counter()
    r = run_setting("setting1")
    sim_wall = time.perf_counter() - t0
    n_total = sum(r[m]["n"] for m in ("single", "centralized", "decentralized"))
    payload["sim"] = {
        "setting": "setting1",
        "wall_s": round(sim_wall, 3),
        "requests_per_s": round(n_total / max(sim_wall, 1e-9), 1),
        "modes": {
            mode: {
                "slo_attainment": round(r[mode]["slo"], 4),
                "avg_latency_s": round(r[mode]["avg_latency"], 2),
                "p95_latency_s": round(r[mode]["p95_latency"], 2),
                "delegation_rate": round(r[mode]["delegation_rate"], 3),
                "n": r[mode]["n"],
            } for mode in ("single", "centralized", "decentralized")
        },
    }

    # --- real engine: slot-based continuous batching vs wave batching ------
    from repro.configs import get_config
    from repro.models import registry
    from repro.serving import Engine, GenRequest
    cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
    params = registry.init(jax.random.PRNGKey(0), cfg)
    prompts = [np.random.default_rng(i).integers(2, 400, size=12 + i)
               .astype(np.int32) for i in range(6)]
    budgets = [4, 32, 4, 32, 4, 16]

    def mk():
        return [GenRequest(rid=f"r{i}", tokens=prompts[i], max_new=budgets[i])
                for i in range(len(prompts))]

    # slot/wave reserve pad(prompt)+pad(max_new) tokens per slot; the paged
    # engine gets the slot engine's MEASURED kv budget as pages but admits
    # on prompt pages only, so more requests are resident concurrently
    # (admitted_concurrency) on the same memory
    page_size = 16
    engine_kw = {
        "slot": dict(max_batch=2, continuous=True),
        "wave": dict(max_batch=2, continuous=False),
        "paged": dict(max_batch=4, paged=True, page_size=page_size),
    }
    engine_out = {}
    for label in ("slot", "wave", "paged"):
        from repro.serving.engine import EngineStats
        eng = Engine(cfg, params, bucket=16, **engine_kw[label])
        eng.serve(mk())          # warm the per-instance jit caches
        eng.stats = EngineStats()
        t0 = time.perf_counter()
        eng.serve(mk())          # timed run reuses the compiled steps
        wall = time.perf_counter() - t0
        snap = eng.load_snapshot()
        engine_out[label] = {
            "max_batch": engine_kw[label]["max_batch"],
            "kv_budget_tokens": snap["kv_budget"],
            "decode_tokens": eng.stats.decode_tokens,
            "decode_steps": eng.stats.decode_steps,
            # decode throughput over wall time spent inside decode_step, so
            # prefill batching differences don't pollute the metric
            "decode_tokens_per_s": round(
                eng.stats.decode_tokens / max(eng.stats.decode_wall_s, 1e-9),
                1),
            "wall_s": round(wall, 3),
            "admitted_concurrency": eng.stats.peak_resident,
        }
        if label == "slot":
            # hand the paged engine exactly the slot engine's KV budget
            engine_kw["paged"]["num_pages"] = snap["kv_budget"] // page_size
        elif label == "paged":
            engine_out[label].update(page_size=page_size,
                                     num_pages=engine_kw[label]["num_pages"],
                                     preempted=eng.stats.preempted)
    payload["engine"] = {"model": cfg.name, **engine_out}

    # --- mixed prompt-heavy/decode-heavy workload: disagg vs colocated ------
    # (DESIGN.md §6.1-disagg) Decode-heavy requests are submitted first and
    # monopolize a colocated engine's two slots for their long decode, so
    # the prompt-heavy requests behind them wait ~the whole decode for their
    # first token.  A disaggregated pair prefills them immediately on the
    # idle prefill engine (which serves the first token), so their TTFT
    # collapses to ~prefill time even while the decode engine is saturated.
    from repro.serving import DisaggEngineExecutor, EngineExecutor
    from repro.serving.engine import EngineStats as _ES

    def mk_mix():
        rng = np.random.default_rng(7)
        reqs = [GenRequest(rid=f"dec{i}",
                           tokens=rng.integers(2, 400, size=8)
                           .astype(np.int32), max_new=48) for i in range(2)]
        reqs += [GenRequest(rid=f"pro{i}",
                            tokens=rng.integers(2, 400, size=96)
                            .astype(np.int32), max_new=4) for i in range(3)]
        return reqs

    def mk_executor(label):
        kw = dict(bucket=16, max_batch=2)
        if label == "slot":
            return EngineExecutor(Engine(cfg, params, **kw))
        if label == "paged":
            return EngineExecutor(Engine(cfg, params, paged=True,
                                         page_size=page_size, num_pages=64,
                                         **kw))
        return DisaggEngineExecutor(
            Engine(cfg, params, paged=True, page_size=page_size, **kw),
            Engine(cfg, params, paged=True, page_size=page_size,
                   num_pages=64, **kw))

    def run_mix(ex, track_inflight=False):
        done = []
        ex.bind(None, lambda r, st_, ft: done.append(r))
        for r in mk_mix():
            assert ex.admit(r)
        # optionally sample the executor-side load report while stepping:
        # disagg surfaces its in-flight KV transfers there (ExecutorLoad
        # .transfer_inflight / .handoff_bytes), so the mix section can
        # record how deep the handoff pipeline actually ran — only done on
        # an UNTIMED pass, so the per-step snapshot cost never perturbs
        # the wall/TTFT numbers tracked PR over PR
        peak_inflight = 0
        while ex.has_work():
            ex.step()
            if track_inflight:
                peak_inflight = max(peak_inflight,
                                    ex.load().transfer_inflight)
        return done, peak_inflight

    mix_out = {}
    for label in MIX_MODES:
        ex = mk_executor(label)
        # warm the per-instance jit caches TWICE: the slot engine's cache
        # capacity grows during the first pass, so only the second pass
        # compiles the shapes the timed run will hit.  The second (warm,
        # untimed, same deterministic workload) pass also records the
        # disagg transfer-pipeline peak.
        run_mix(ex)
        _, peak_inflight = run_mix(ex, track_inflight=(label == "disagg"))
        engines = ([ex.prefill, ex.decode] if label == "disagg"
                   else [ex.engine])
        for e in engines:
            e.stats = _ES()
        t0 = time.perf_counter()
        done, _ = run_mix(ex)            # timed run reuses compiled steps
        wall = time.perf_counter() - t0
        st = ex.engine_stats()
        ttft = {r.rid: r.first_token_at - r.enqueued_at for r in done}
        mix_out[label] = {
            "served": len(done),
            "avg_ttft_prompt_heavy_s": round(float(np.mean(
                [v for k, v in ttft.items() if k.startswith("pro")])), 4),
            "avg_ttft_decode_heavy_s": round(float(np.mean(
                [v for k, v in ttft.items() if k.startswith("dec")])), 4),
            "decode_tokens_per_s": round(
                st.decode_tokens / max(st.decode_wall_s, 1e-9), 1),
            "wall_s": round(wall, 3),
        }
        if label == "disagg":
            mix_out[label].update(handoffs=st.handoffs,
                                  handoff_bytes=st.handoff_bytes,
                                  transfer_inflight_peak=peak_inflight)
    payload["mix"] = {
        "workload": "2 decode-heavy (prompt 8, out 48) then "
                    "3 prompt-heavy (prompt 96, out 4), max_batch 2",
        "ttft_speedup_prompt_heavy": round(
            mix_out["paged"]["avg_ttft_prompt_heavy_s"]
            / max(mix_out["disagg"]["avg_ttft_prompt_heavy_s"], 1e-9), 2),
        **mix_out,
    }

    # --- decode-heavy workload: speculative vs plain paged (§6.1-spec) ------
    # The draft here IS the target (same params), the regime where drafts
    # always agree, so every verify forward emits spec_k + 1 tokens.
    # decode_tokens_per_s is EFFECTIVE target-side decode throughput:
    # emitted tokens over wall time inside target decode/verify jits — the
    # draft's own (stand-in, full-size) cost is reported separately as
    # spec.draft_wall_s, since a production draft is ~10x smaller.
    from repro.serving import SpecEngineExecutor
    from repro.sim.executor import spec_expected_tokens
    spec_k = 4

    def mk_spec():
        rng = np.random.default_rng(11)
        return [GenRequest(rid=f"s{i}",
                           tokens=rng.integers(2, 400, size=10)
                           .astype(np.int32), max_new=40) for i in range(3)]

    def run_spec(ex):
        done = []
        ex.bind(None, lambda r, st_, ft: done.append(r))
        for r in mk_spec():
            assert ex.admit(r)
        while ex.has_work():
            ex.step()
        return done

    spec_out = {}
    for label in SPEC_MODES:
        # ample page pool (num_pages=64) on BOTH engines: recompute
        # preemption would replay tokens and pollute the throughput
        # comparison with recompute work
        if label == "paged":
            ex = EngineExecutor(Engine(cfg, params, bucket=16, max_batch=3,
                                       paged=True, page_size=page_size,
                                       num_pages=64))
        else:
            ex = SpecEngineExecutor(Engine(
                cfg, params, bucket=16, max_batch=3, paged=True,
                page_size=page_size, num_pages=64,
                spec_draft=(cfg, params), spec_k=spec_k))
        run_spec(ex)
        run_spec(ex)                     # warm the per-instance jit caches
        eng = ex.engine
        eng.stats = _ES()
        if label == "spec":
            eng.spec_accept_hist = [0] * (spec_k + 1)
        t0 = time.perf_counter()
        done = run_spec(ex)              # timed run reuses compiled steps
        wall = time.perf_counter() - t0
        st = ex.engine_stats()
        spec_out[label] = {
            "served": len(done),
            "decode_tokens": st.decode_tokens,
            "decode_tokens_per_s": round(
                st.decode_tokens / max(st.decode_wall_s, 1e-9), 1),
            "wall_s": round(wall, 3),
        }
        if label == "spec":
            spec_out[label].update(
                accept_hist=list(eng.spec_accept_hist),
                alpha_ema=round(eng.spec_alpha, 4),
                expected_tokens_per_step=round(
                    spec_expected_tokens(eng.spec_alpha, spec_k), 3),
                draft_wall_s=round(st.draft_wall_s, 3),
                verify_steps=st.spec_steps)
    payload["spec"] = {
        "workload": "3 decode-heavy requests (prompt 10, out 40), "
                    "max_batch 3; draft = target (always agrees)",
        "spec_k": spec_k,
        "speedup_decode_tokens_per_s": round(
            spec_out["spec"]["decode_tokens_per_s"]
            / max(spec_out["paged"]["decode_tokens_per_s"], 1e-9), 2),
        **spec_out,
    }

    # --- pinned kernel microbench (DESIGN.md §Perf-kernels) -----------------
    # Fixed shapes, interpret mode, forced Pallas path: slot (contiguous
    # cache) vs paged (block tables) vs quantized-paged (int8 pools + scale
    # pools) decode, plus the multi-token spec-verify pair.  The fp paged
    # entries are bit-exactness-tested elsewhere (tests/test_kernels.py);
    # here the timings and the autotuned pages_per_step are tracked PR over
    # PR so a grid/tuning regression shows up in the artifact diff.
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.ref import paged_decode_ref, paged_decode_quant_ref
    from repro.kernels.tuning import autotune_paged_decode
    from repro.models.attention import kv_quantize

    kb, kh, khkv, kd = 2, 8, 2, 64
    kpage, kmaxp, kpool, spec_k = 16, 4, 16, 3
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    kq1 = jax.random.normal(ks[0], (kb, 1, kh, kd), jnp.float32)
    kqv = jax.random.normal(ks[1], (kb, spec_k + 1, kh, kd), jnp.float32)
    kp = jax.random.normal(ks[2], (kpool, kpage, khkv, kd), jnp.float32)
    vp = jax.random.normal(ks[3], (kpool, kpage, khkv, kd), jnp.float32)
    kbt = jnp.arange(kb * kmaxp, dtype=jnp.int32).reshape(kb, kmaxp)
    klens = jnp.asarray([40, 57], jnp.int32)
    kq_i8, k_scale = kv_quantize(kp)
    vq_i8, v_scale = kv_quantize(vp)
    kcache = kp[:kb * kmaxp].reshape(kb, kmaxp * kpage, khkv, kd)
    vcache = vp[:kb * kmaxp].reshape(kb, kmaxp * kpage, khkv, kd)
    kcl = jnp.asarray(57, jnp.int32)

    def _us(fn, *args, iters=3, **kw):
        jax.block_until_ready(fn(*args, **kw))       # warm / trace
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args, **kw))
        return round((time.perf_counter() - t0) / iters * 1e6, 1)

    tuned = autotune_paged_decode(kq1, kp, vp, kbt, klens,
                                  candidates=(1, 2, 4))
    pps = tuned.pages_per_step
    out_paged = ops.paged_decode(kq1, kp, vp, kbt, klens, backend="pallas",
                                 pages_per_step=pps)
    err_paged = float(jnp.max(jnp.abs(
        out_paged - paged_decode_ref(kq1, kp, vp, kbt, klens))))
    out_quant = ops.paged_decode_quant(kq1, kq_i8, vq_i8, k_scale, v_scale,
                                       kbt, klens, backend="pallas",
                                       pages_per_step=pps)
    err_quant = float(jnp.max(jnp.abs(
        out_quant - paged_decode_quant_ref(kq1, kq_i8, vq_i8, k_scale,
                                           v_scale, kbt, klens))))
    payload["kernel"] = {
        "shapes": {"batch": kb, "heads": kh, "kv_heads": khkv,
                   "head_dim": kd, "page_size": kpage, "pages_per_row": kmaxp,
                   "pool_pages": kpool, "spec_k": spec_k},
        "tuning": {"page_size": kpage, "head_dim": kd, "hkv": khkv,
                   "pages_per_step": pps},
        "decode": {
            "slot": {"us_per_call": _us(
                ops.decode, kq1, kcache, vcache, kcl, backend="pallas")},
            "paged": {"us_per_call": _us(
                ops.paged_decode, kq1, kp, vp, kbt, klens,
                backend="pallas", pages_per_step=pps),
                "max_err_vs_oracle": round(err_paged, 8)},
            "paged_quant": {"us_per_call": _us(
                ops.paged_decode_quant, kq1, kq_i8, vq_i8, k_scale, v_scale,
                kbt, klens, backend="pallas", pages_per_step=pps),
                "max_err_vs_oracle": round(err_quant, 8)},
        },
        "spec_verify": {
            "paged": {"us_per_call": _us(
                ops.paged_verify, kqv, kp, vp, kbt, klens,
                backend="pallas", pages_per_step=pps)},
            "paged_quant": {"us_per_call": _us(
                ops.paged_verify_quant, kqv, kq_i8, vq_i8, k_scale, v_scale,
                kbt, klens, backend="pallas", pages_per_step=pps)},
        },
    }

    # int8 admission demo: same tight page budget, 8 queued one-page
    # requests (prompt 15 + 1 new token stays inside one 16-token page) —
    # the kv_quant engine's doubled pool (repro.sim.executor
    # .quantized_pages) must keep >= 2x the concurrent residents
    adm_pages = 4
    adm_out = {}
    for label, quant in (("paged", False), ("paged_quant", True)):
        acfg = cfg.replace(kv_quant=True) if quant else cfg
        eng = Engine(acfg, params, max_batch=8, bucket=16, paged=True,
                     page_size=page_size, num_pages=adm_pages)
        reqs = [GenRequest(rid=f"adm{i}",
                           tokens=np.arange(2, 17).astype(np.int32),
                           max_new=1) for i in range(8)]
        eng.serve(reqs)
        adm_out[label] = eng.stats.peak_resident
    payload["kernel"]["admission"] = {
        "num_pages": adm_pages, "page_size": page_size, **adm_out}

    # --- gossip scale-out: digest vs probe routing (DESIGN.md §6.2-gossip) --
    from benchmarks.scaling import gossip_scaling_section
    payload["gossip"] = gossip_scaling_section()

    # --- cross-request prefix caching (DESIGN.md §6.1-prefix) ---------------
    # (a) real engine: cold prefill of a long shared prefix vs a cached hit
    # on the same prefix with a fresh suffix.  Both prompt shapes are
    # identical; the jit caches for BOTH the cold-prefill and warm-prefill
    # paths are compiled untimed on a throwaway prefix first, so the timed
    # TTFTs compare page reuse, not compilation.
    pfx_tokens, sfx_tokens = 192, 8
    rngp = np.random.default_rng(23)
    bench_prefix = rngp.integers(2, 400, size=pfx_tokens).astype(np.int32)
    jit_prefix = rngp.integers(2, 400, size=pfx_tokens).astype(np.int32)

    def pfx_req(rid, prefix, sufseed):
        suf = np.random.default_rng(sufseed).integers(
            2, 400, size=sfx_tokens).astype(np.int32)
        return GenRequest(rid=rid, tokens=np.concatenate([prefix, suf]),
                          max_new=4)

    peng = Engine(cfg, params, bucket=16, max_batch=2, paged=True,
                  page_size=page_size, num_pages=96, prefix_cache=True)
    peng.serve([pfx_req("jit-cold", jit_prefix, 1)])   # compiles cold path
    peng.serve([pfx_req("jit-warm", jit_prefix, 2)])   # compiles warm path
    cold_done = peng.serve([pfx_req("cold", bench_prefix, 3)])
    hit_before = peng.prefix_hit_tokens
    warm_done = peng.serve([pfx_req("hit", bench_prefix, 4)])
    cold_ttft = cold_done[0].first_token_at - cold_done[0].enqueued_at
    cached_ttft = warm_done[0].first_token_at - warm_done[0].enqueued_at
    psnap = peng.load_snapshot()

    # (b) simulated zipf-shared-prefix workload on one prefix-cache backend
    from repro.core.node import QueuedRequest
    from repro.sim import TokenBucketExecutor, make_profile
    from repro.sim.events import EventLoop
    from repro.sim.workload import make_zipf_prefix_requests
    zloop = EventLoop()
    zex = TokenBucketExecutor(make_profile(quality=0.6),
                              page_size=page_size, prefix_cache=True)
    zserved = []
    zex.bind(zloop, lambda qr, st_, ft: zserved.append(qr))

    def zsubmit(qr):
        if not zex.admit(qr):
            zloop.schedule(0.5, lambda: zsubmit(qr))

    for zr in make_zipf_prefix_requests(300, ["n0"], seed=23, n_prefixes=8):
        zloop.schedule(zr.arrival, lambda zr=zr: zsubmit(
            QueuedRequest(zr, zr.arrival, False, "n0")))
    zloop.run(until=10000.0)
    zhit_rate = zex.prefix_hit_tokens / max(1, zex.prefix_lookup_tokens)

    # (c) cache-affinity vs affinity-blind gossip dispatch on a hot-origin
    # zipf workload: every request lands on one node, which must offload
    # most of them — with more live prefixes (24) than one node's
    # fingerprint window (PREFIX_FINGERPRINT_K), where the dispatch choice
    # decides the aggregate hit rate
    from repro.core import Network, Node, NodePolicy
    from repro.core.duel import DuelParams
    from repro.sim import BackendProfile

    def _affinity_point(affinity):
        net = Network(mode="decentralized", seed=0, init_balance=100.0,
                      duel=DuelParams(p_d=0.0, k_judges=0),
                      gossip_interval=0.25, cache_affinity=affinity)
        pol = NodePolicy(accept_freq=1.0, offload_freq=1.0,
                         offload_queue_threshold=0)
        prof = BackendProfile(prefill_tps=1e4, decode_tps=300.0,
                              saturation=2, max_concurrency=8, quality=0.6,
                              kv_token_budget=16384)
        for i in range(8):
            net.add_node(Node(
                f"n{i}", prof, policy=pol,
                executor_factory=lambda node: TokenBucketExecutor(
                    node.profile, page_size=page_size, prefix_cache=True)))
        reqs = make_zipf_prefix_requests(
            500, ["n0"], seed=100, n_prefixes=24, prefix_tokens=512,
            suffix_mean=24, mean_interarrival=0.05, output_mean=48)
        net.run(list(reqs), until=400.0)
        hit = sum(n.executor.prefix_hit_tokens for n in net.nodes.values())
        look = sum(n.executor.prefix_lookup_tokens
                   for n in net.nodes.values())
        return {"hit_rate": round(hit / max(1, look), 4),
                "hit_tokens": hit, "lookup_tokens": look, "n": len(reqs)}

    payload["prefix_cache"] = {
        "workload": f"engine: prefix {pfx_tokens} + suffix {sfx_tokens}, "
                    "cold then cached; sim: 300 zipf requests over 8 "
                    "prefixes; routing: 500 hot-origin zipf requests over "
                    "24 prefixes, 8 nodes",
        "engine": {
            "cold_ttft_s": round(cold_ttft, 4),
            "cached_ttft_s": round(cached_ttft, 4),
            "ttft_speedup": round(cold_ttft / max(cached_ttft, 1e-9), 2),
            "hit_tokens": peng.prefix_hit_tokens - hit_before,
            "cached_pages": psnap["cached_pages"],
            "prefix_tokens": pfx_tokens,
            "suffix_tokens": sfx_tokens,
        },
        "sim": {
            "hit_rate": round(zhit_rate, 4),
            "hit_tokens": zex.prefix_hit_tokens,
            "lookup_tokens": zex.prefix_lookup_tokens,
            "served": len(zserved),
        },
        "routing": {
            "affinity": _affinity_point(True),
            "blind": _affinity_point(False),
        },
    }

    # --- tracing overhead: mix decode throughput, traced vs untraced --------
    # (DESIGN.md §Observability) Same paged executor and deterministic mix
    # workload as the mix section; the traced arm runs under a live Tracer
    # so every engine.prefill/engine.decode_step wall span is recorded.
    # Best-of-two decode tok/s per arm so a one-off GC/scheduler hiccup
    # doesn't trip the pinned >= 0.95x bound.
    from repro.obs import MetricsRegistry, Tracer, set_registry, set_tracer

    def obs_arm(traced):
        ex = mk_executor("paged")
        run_mix(ex)
        run_mix(ex)                  # warm the per-instance jit caches twice
        tr = Tracer()
        old_tr = set_tracer(tr) if traced else None
        try:
            best = None
            for _ in range(2):
                ex.engine.stats = _ES()
                t0 = time.perf_counter()
                run_mix(ex)          # timed run reuses compiled steps
                wall = time.perf_counter() - t0
                st = ex.engine_stats()
                tps = st.decode_tokens / max(st.decode_wall_s, 1e-9)
                if best is None or tps > best["decode_tokens_per_s"]:
                    best = {"decode_tokens": st.decode_tokens,
                            "decode_tokens_per_s": round(tps, 1),
                            "wall_s": round(wall, 3)}
        finally:
            if traced:
                set_tracer(old_tr)
        return best, len(tr.spans)

    obs_reg = MetricsRegistry()
    old_reg = set_registry(obs_reg)
    try:
        obs_untraced, _ = obs_arm(False)
        obs_traced, obs_spans = obs_arm(True)
    finally:
        set_registry(old_reg)
    # the engine counters only fire under pressure (preemption, prefix
    # hits) and the mix fits in budget, so fold in the routing-plane
    # counters from the traced sim mix too — the artifact then shows
    # the labeled series (net.messages{kind=...}) the registry carries
    _sim_m, _sim_tr, sim_net = _traced_sim_mix(n_requests=12)
    obs_counters = dict(obs_reg.snapshot()["counters"])
    obs_counters.update(sim_net.registry.snapshot()["counters"])
    payload["obs"] = {
        "workload": "mix workload on the paged executor, best-of-two "
                    "decode tok/s per arm, tracer off vs on",
        "untraced": obs_untraced,
        "traced": obs_traced,
        "overhead_ratio": round(
            obs_traced["decode_tokens_per_s"]
            / max(obs_untraced["decode_tokens_per_s"], 1e-9), 4),
        "spans": obs_spans,
        "metrics": obs_counters,
    }

    # --- static-analysis snapshot (DESIGN.md §7) ----------------------------
    from repro.analysis import run_analysis
    lint_report = run_analysis(_REPO)
    payload["lint"] = {
        "rules": lint_report.rules,
        "new": len(lint_report.new),
        "suppressed": len(lint_report.suppressed),
        "baselined": len(lint_report.baselined),
        "wall_s": round(lint_report.wall_s, 3),
    }

    check_bench_schema(payload)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    print(json.dumps(payload, indent=2, sort_keys=True))
    return 0


def _full() -> int:
    rows: List[str] = ["name,us_per_call,derived"]
    from benchmarks import (duel_overhead, dynamic, gametheory, kernels,
                            policies, protocol, quality, scheduling)
    for mod, label in ((scheduling, "scheduling (Fig4/Tab2)"),
                       (dynamic, "dynamic participation (Fig5)"),
                       (quality, "quality incentivization (Fig6)"),
                       (duel_overhead, "duel overhead (Fig7)"),
                       (policies, "user-level policies (Fig8)"),
                       (gametheory, "game theory (Sec5)"),
                       (protocol, "protocol: ledger ablation + gossip (AppA2/C)"),
                       (kernels, "pallas kernels")):
        t0 = time.perf_counter()
        mod.main(rows)
        dt = time.perf_counter() - t0
        print(f"# {label}: {dt:.1f}s", file=sys.stderr, flush=True)
    print("\n".join(rows))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="benchmark harness")
    ap.add_argument("--smoke", action="store_true",
                    help="<60s end-to-end sanity pass instead of the full "
                         "benchmark sweep")
    ap.add_argument("--bench", action="store_true",
                    help="emit machine-readable BENCH_scheduling.json "
                         "(SLO/latency per mode, sim req/s, engine decode "
                         "tokens/s)")
    ap.add_argument("--bench-out", default="BENCH_scheduling.json",
                    help="output path for --bench")
    ap.add_argument("--lint", action="store_true",
                    help="run the AST invariant linter (repro.analysis) "
                         "only; <10s, no jax import")
    ap.add_argument("--trace", metavar="PATH",
                    help="run the traced sim mix and write a "
                         "Perfetto/Chrome trace_event JSON to PATH; "
                         "prints the per-request latency breakdown and "
                         "asserts the span latency partition; <10s, no "
                         "jax import")
    args = ap.parse_args(argv)
    if args.lint:
        return _lint()
    if args.trace:
        return _trace(args.trace)
    if args.smoke:
        return _smoke()
    if args.bench:
        return _bench(args.bench_out)
    return _full()


if __name__ == "__main__":
    sys.exit(main())
