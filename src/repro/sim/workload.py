"""Piecewise-Poisson request workloads (paper Table 3).

Each node's user traffic is a piecewise-homogeneous Poisson process: a list of
``(t_start, t_end, mean_interarrival_s)`` intervals.  Request lengths are drawn
from a seeded lognormal-ish distribution mimicking OpenR1-Math-220k reasoning
prompts (long outputs, max_tokens 8192 per paper Appendix C).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: str
    origin: str            # node id where the user submitted it
    arrival: float         # sim time of user submission
    prompt_tokens: int
    output_tokens: int
    slo_s: float           # latency threshold for SLO attainment
    is_duel_extra: bool = False   # challenger / judge traffic (excluded from SLO)


@dataclass(frozen=True)
class ArrivalPhase:
    t_start: float
    t_end: float
    mean_interarrival: float   # 1/lambda, seconds


@dataclass
class WorkloadSpec:
    """Per-node arrival schedule, as in paper Table 3."""

    node_id: str
    phases: Sequence[ArrivalPhase]
    prompt_mean: int = 512
    output_mean: int = 2048       # reasoning traces are long
    max_tokens: int = 8192        # paper: max token length 8192
    slo_s: float = 300.0

    def arrivals(self, rng: np.random.Generator) -> List[Tuple[float, int, int]]:
        """Materialize (time, prompt_tokens, output_tokens) arrivals."""
        out: List[Tuple[float, int, int]] = []
        for ph in self.phases:
            t = ph.t_start
            while True:
                t += rng.exponential(ph.mean_interarrival)
                if t >= ph.t_end:
                    break
                p = int(np.clip(rng.lognormal(np.log(self.prompt_mean), 0.6), 16, 4096))
                o = int(np.clip(rng.lognormal(np.log(self.output_mean), 0.7), 32, self.max_tokens))
                out.append((t, p, o))
        out.sort(key=lambda x: x[0])
        return out


def make_requests(specs: Sequence[WorkloadSpec], seed: int) -> List[Request]:
    """Materialize the full multi-node workload deterministically."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for spec in specs:
        for i, (t, p, o) in enumerate(spec.arrivals(rng)):
            reqs.append(Request(
                rid=f"{spec.node_id}-r{i}", origin=spec.node_id, arrival=t,
                prompt_tokens=p, output_tokens=o, slo_s=spec.slo_s))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


def uniform_phases(t_end: float, mean_interarrival: float) -> List[ArrivalPhase]:
    return [ArrivalPhase(0.0, t_end, mean_interarrival)]


def two_phase(split: float, t_end: float, ia1: float, ia2: float) -> List[ArrivalPhase]:
    return [ArrivalPhase(0.0, split, ia1), ArrivalPhase(split, t_end, ia2)]
