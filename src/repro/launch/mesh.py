"""Production mesh builders (TPU v5e pods; 512 host devices in the dry-run).

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.
"""

from __future__ import annotations

from repro.compat import meshenv


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return meshenv.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke tests / examples on this CPU container."""
    return meshenv.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW_PER_LINK = 50e9       # bytes/s per link
