"""Whisper-base [arXiv:2212.04356] — enc-dec, conv frontend stubbed."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                  # decoder layers
    n_encoder_layers=6,
    encoder_seq=1500,            # 30 s of audio after the (stub) conv frontend
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    norm_type="layernorm",
    act="gelu",
    use_bias=True,
    tie_embeddings=True,
)
