"""Event loop, workload, metrics, paper-claims integration, serving engine."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.sim import (EventLoop, MetricsCollector, WorkloadSpec,
                       make_profile, make_requests, uniform_phases)
from repro.sim.metrics import CompletedRequest


class TestEventLoop:
    def test_ordering_and_ties(self):
        loop = EventLoop()
        seen = []
        loop.schedule(2.0, lambda: seen.append("b"))
        loop.schedule(1.0, lambda: seen.append("a"))
        loop.schedule(2.0, lambda: seen.append("c"))   # tie: FIFO
        loop.run()
        assert seen == ["a", "b", "c"]

    def test_until_resume(self):
        loop = EventLoop()
        seen = []
        for t in (1.0, 5.0, 9.0):
            loop.schedule(t, lambda t=t: seen.append(t))
        loop.run(until=6.0)
        assert seen == [1.0, 5.0] and loop.now == 6.0
        loop.run()
        assert seen == [1.0, 5.0, 9.0]

    def test_cancel(self):
        loop = EventLoop()
        seen = []
        ev = loop.schedule(1.0, lambda: seen.append(1))
        loop.cancel(ev)
        loop.run()
        assert seen == []


class TestWorkload:
    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_arrivals_sorted_within_phases(self, seed):
        specs = [WorkloadSpec("n1", uniform_phases(100.0, 5.0))]
        reqs = make_requests(specs, seed)
        times = [r.arrival for r in reqs]
        assert times == sorted(times)
        assert all(0 <= t < 100.0 for t in times)
        assert all(r.output_tokens <= specs[0].max_tokens for r in reqs)

    def test_rate_matches_lambda(self):
        specs = [WorkloadSpec("n1", uniform_phases(10_000.0, 4.0))]
        reqs = make_requests(specs, seed=0)
        assert len(reqs) == pytest.approx(2500, rel=0.1)


class TestMetrics:
    def _mk(self, lat, slo=10.0, extra=False):
        return CompletedRequest("r", "n", "n", 0.0, lat, slo, False, extra)

    def test_slo_and_percentiles(self):
        m = MetricsCollector()
        for lat in (1.0, 5.0, 9.0, 20.0):
            m.record(self._mk(lat))
        assert m.slo_attainment() == pytest.approx(0.75)
        assert m.avg_latency() == pytest.approx(8.75)

    def test_duel_extras_excluded(self):
        m = MetricsCollector()
        m.record(self._mk(1.0))
        m.record(self._mk(100.0, extra=True))
        assert m.slo_attainment() == 1.0
        assert m.avg_latency() == pytest.approx(1.0)

    def test_slo_curve_monotone(self):
        m = MetricsCollector()
        for lat in np.linspace(1, 30, 20):
            m.record(self._mk(float(lat)))
        curve = m.slo_curve([0.5, 1.0, 2.0, 4.0])
        vals = [v for _, v in curve]
        assert vals == sorted(vals)


class TestPaperClaims:
    """Integration: the three headline claims of §6.1 hold in our repro."""

    @pytest.fixture(scope="class")
    def results(self):
        from benchmarks.scheduling import run_setting
        return run_setting("setting1")

    def test_decentralized_beats_single(self, results):
        assert results["decentralized"]["slo"] >= results["single"]["slo"]
        assert (results["decentralized"]["avg_latency"]
                < results["single"]["avg_latency"])

    def test_near_centralized(self, results):
        # within 10 SLO points of the omniscient scheduler
        assert (results["centralized"]["slo"]
                - results["decentralized"]["slo"]) < 0.10

    def test_latency_reduction_magnitude(self, results):
        """paper: latency reduced by up to 27.6% — ours is in that regime"""
        gain = 1 - (results["decentralized"]["avg_latency"]
                    / results["single"]["avg_latency"])
        assert gain > 0.15


class TestEngine:
    def test_generates_and_counts(self):
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, max_batch=2, bucket=16)
        reqs = [GenRequest(rid=f"r{i}",
                           tokens=np.random.default_rng(i).integers(
                               2, 400, size=12).astype(np.int32),
                           max_new=4) for i in range(3)]
        done = eng.serve(reqs)
        assert all(r.result is not None and len(r.result) >= 1 for r in done)
        assert eng.stats.served == 3
        lp = eng.logprob_of(np.arange(2, 20).astype(np.int32))
        assert np.isfinite(lp) and lp < 0

    def test_per_request_temperature_and_budget(self):
        """A hot request in the batch must not heat up its greedy neighbour,
        and each request stops at ITS max_new, not the batch max."""
        from repro.configs import get_config
        from repro.models import registry
        from repro.serving import Engine, GenRequest
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        prompt = np.random.default_rng(7).integers(
            2, 400, size=12).astype(np.int32)
        eng = Engine(cfg, params, max_batch=2, bucket=16)
        done = eng.serve([
            GenRequest(rid="greedy", tokens=prompt, max_new=6,
                       temperature=0.0),
            GenRequest(rid="hot", tokens=prompt, max_new=3,
                       temperature=5.0),
        ])
        assert len(done[0].result) <= 6
        assert len(done[1].result) <= 3          # own budget, not batch max
        solo = Engine(cfg, params, max_batch=2, bucket=16, seed=99).serve(
            [GenRequest(rid="solo", tokens=prompt, max_new=6,
                        temperature=0.0)])
        np.testing.assert_array_equal(done[0].result, solo[0].result)
