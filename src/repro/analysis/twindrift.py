"""twin-drift: the sim twin and the engines must share one source of truth.

The analytic simulator (``repro.sim``) is the executable spec the serving
engines are validated against (DESIGN.md §6.2, §6.3): the twin tests
assert that ``EngineExecutor`` and ``TokenBucketExecutor`` agree because
they *compute from the same constants and predicates*.  That guarantee
dies silently the moment an engine module re-defines ``SPEC_K`` or
re-implements ``paged_admit_ok`` locally — both copies keep passing their
own tests while drifting apart.  Two sub-rules:

* ``twin-drift/shared-name`` — names exported by the service model
  (public ``ALL_CAPS`` constants of ``repro.sim.servicemodel``) and the
  shared admission predicates of ``repro.sim.executor`` may not be
  re-defined by any other ``src/`` or ``benchmarks/`` module; import them.
* ``twin-drift/duplicate-const`` — a public ``ALL_CAPS`` module-level
  constant literal defined under the same name in two or more ``src/``
  modules is a drift hazard even when the values currently agree; hoist
  one definition and import it.  (Private ``_NAME`` constants are
  exempt — the leading underscore is an explicit claim of module-local
  meaning.)
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Tuple

from repro.analysis.astutil import const_literal
from repro.analysis.framework import Checker, Finding, RepoIndex, register

# where the shared vocabulary is defined
SERVICEMODEL = "src/repro/sim/servicemodel.py"
SIM_EXECUTOR = "src/repro/sim/executor.py"
SIM_PREFIX = "src/repro/sim/"

# admission/cost predicates shared by sim twins and engines alike
SHARED_PREDICATES = frozenset({"pages_for", "paged_admit_ok",
                               "quantized_pages", "spec_expected_tokens",
                               "digest_staleness_weight",
                               "prefix_hit_pages", "prefix_fingerprint_id"})


def _is_shared_const_name(name: str) -> bool:
    return (name.isupper() and not name.startswith("_")
            and any(c.isalpha() for c in name))


def _module_constants(tree: ast.Module) -> Dict[str, Tuple[int, ast.AST]]:
    """Public ALL_CAPS module-level assignments: name -> (line, value)."""
    out: Dict[str, Tuple[int, ast.AST]] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and _is_shared_const_name(tgt.id):
                    out[tgt.id] = (node.lineno, node.value)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.value is not None \
                and _is_shared_const_name(node.target.id):
            out[node.target.id] = (node.lineno, node.value)
    return out


@register
class TwinDriftChecker(Checker):
    rule_id = "twin-drift"
    description = ("engines import sim/servicemodel constants and "
                   "predicates instead of re-defining them; no duplicated "
                   "ALL_CAPS constant literals across src/ modules")

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        yield from self._shared_names(repo)
        yield from self._duplicate_consts(repo)

    # --------------------------------------------------------- shared names
    def _shared_names(self, repo: RepoIndex) -> Iterable[Finding]:
        vocab: Dict[str, str] = {}          # name -> defining module
        sm_tree = repo.tree(SERVICEMODEL) if repo.exists(SERVICEMODEL) \
            else None
        if sm_tree is not None:
            for name in _module_constants(sm_tree):
                vocab[name] = "repro.sim.servicemodel"
        for name in SHARED_PREDICATES:
            vocab[name] = "repro.sim.executor"
        if not vocab:
            return

        for rel in repo.py_files():
            if rel.startswith(SIM_PREFIX) or rel.startswith("tests/"):
                continue          # the home itself; tests may build fakes
            if not (rel.startswith("src/") or rel.startswith("benchmarks/")):
                continue
            tree = repo.tree(rel)
            if tree is None:
                continue
            for node in ast.walk(tree):
                hits: List[Tuple[str, int]] = []
                if isinstance(node, ast.Assign):
                    hits = [(t.id, node.lineno) for t in node.targets
                            if isinstance(t, ast.Name) and t.id in vocab]
                elif isinstance(node, ast.AnnAssign) \
                        and isinstance(node.target, ast.Name) \
                        and node.target.id in vocab:
                    hits = [(node.target.id, node.lineno)]
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
                        and node.name in vocab:
                    hits = [(node.name, node.lineno)]
                for name, line in hits:
                    yield Finding(
                        "twin-drift/shared-name", rel, line,
                        f"re-defines '{name}', which is owned by "
                        f"{vocab[name]}; import it so the sim twin and "
                        f"the engines cannot drift apart")

    # ----------------------------------------------------- duplicate consts
    def _duplicate_consts(self, repo: RepoIndex) -> Iterable[Finding]:
        # name -> [(rel, line, value)] across src/ modules
        sites: Dict[str, List[Tuple[str, int, object]]] = {}
        for rel in repo.py_files():
            if not rel.startswith("src/"):
                continue
            tree = repo.tree(rel)
            if tree is None:
                continue
            for name, (line, value) in _module_constants(tree).items():
                ok, lit = const_literal(value)
                if ok:
                    sites.setdefault(name, []).append((rel, line, lit))

        for name, defs in sorted(sites.items()):
            if len(defs) < 2:
                continue
            paths = sorted(d[0] for d in defs)
            for rel, line, _lit in sorted(defs):
                others = ", ".join(p for p in paths if p != rel)
                yield Finding(
                    "twin-drift/duplicate-const", rel, line,
                    f"constant '{name}' is also defined in {others}; "
                    f"hoist one shared definition and import it "
                    f"(same-value copies still drift)")
