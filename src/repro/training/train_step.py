"""Cross-entropy train step, generic over the model registry.

Supports microbatch gradient accumulation via an inner ``lax.scan`` — this is
how the 100B+ configs keep per-layer activation memory bounded on v5e (see
EXPERIMENTS.md §Perf), and it also bounds MoE dispatch buffers.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean CE over (B, S); labels < vocab_size; padded classes never appear."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(params, cfg: ModelConfig, batch: Dict, aux_weight: float = 0.01,
            **apply_kw) -> Tuple[jax.Array, Dict]:
    logits, aux = registry.apply_with_aux(params, cfg, batch, **apply_kw)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def _split_microbatch(batch: Dict, n: int, i: jax.Array) -> Dict:
    def slc(x):
        mb = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree.map(slc, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, **apply_kw):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}; batch contains "labels" plus model
    inputs.  With microbatches > 1, gradients are accumulated over equal
    slices of the (global) batch dimension inside a lax.scan.
    """

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, **apply_kw)
        return loss, parts, grads

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            loss, parts, grads = grads_of(params, batch)
        else:
            def mb_step(carry, i):
                acc, loss_acc = carry
                mb = _split_microbatch(batch, microbatches, i)
                loss, _, g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                mb_step, (zero, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        new_params, new_opt, om = adamw_update(opt_cfg, params, grads,
                                               state["opt"])
        metrics = {"loss": loss, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_state(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    params = registry.init(key, cfg)
    return {"params": params, "opt": init_opt_state(params)}


def state_shape(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the train state — dry-run path."""
    return jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0), cfg))
