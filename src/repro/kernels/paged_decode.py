"""Pallas TPU paged flash-decode: block-table attention over a KV page pool.

The paged serving engine (DESIGN.md §6.1, paged backend) stores KV in a
shared pool of fixed-size pages; each sequence owns a per-row *block table*
mapping logical page index -> physical page.  Decode attention then has no
contiguous cache to stream — the kernel walks a sequence's pages in logical
order and resolves each one through the block table.

The resolution happens in the BlockSpec ``index_map`` via scalar prefetch:
the block table and per-row lengths are prefetched to SMEM before the body
runs, so the pager can issue the HBM->VMEM DMA for physical page
``bt[b, ip]`` while the previous page is still being processed — the same
streaming shape as the contiguous kernel in ``flash_decode.py``, just with
one indirection on the page address.  One grid step covers one page per
(batch row × kv head); the online-softmax carry lives in VMEM scratch.

Entries of the block table past a row's allocated pages may point anywhere
(the engine points them at the scratch page 0); they are DMA'd but fully
masked by ``lengths``.  The jnp oracle is ``ref.paged_decode_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat.pallascompat import tpu_compiler_params
from repro.models.attention import NEG_INF


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, hkv: int,
                  scale: float):
    ip = pl.program_id(1)
    np_ = pl.num_programs(1)
    cache_len = len_ref[pl.program_id(0) // hkv]

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                   # (rep, d)
    k = k_ref[0].astype(jnp.float32)                   # (page, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    # logical token positions of this page; garbage pages (block-table
    # entries past the row's allocation) mask out entirely here
    k_pos = ip * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    s = jnp.where(k_pos < cache_len, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(ip == np_ - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_paged_decode_tpu(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = True) -> jax.Array:
    """q: (B, 1, H, D); pools: (P, page, Hkv, D); block_tables: (B, maxp)
    int32; lengths: (B,) int32 valid tokens per row.

    Returns (B, 1, H, D).
    """
    b, _, h, d = q.shape
    page, hkv = k_pool.shape[1], k_pool.shape[2]
    maxp = block_tables.shape[1]
    assert h % hkv == 0
    rep = h // hkv

    qr = q.reshape(b, hkv, rep, d).reshape(b * hkv, rep, d)
    # (P, page, Hkv, D) -> (P*Hkv, page, D) so one block is one page of one
    # kv head, addressable by a single leading block index
    kr = k_pool.transpose(0, 2, 1, 3).reshape(-1, page, d)
    vr = v_pool.transpose(0, 2, 1, 3).reshape(-1, page, d)
    bt = block_tables.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    def kv_index(bh, ip, bt_ref, len_ref):
        # physical page for (row bh//hkv, logical page ip), head bh%hkv
        return (bt_ref[bh // hkv, ip] * hkv + bh % hkv, 0, 0)

    grid = (b * hkv, maxp)
    kernel = functools.partial(_paged_kernel, page=page, hkv=hkv,
                               scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, rep, d), lambda bh, ip, bt, ln: (bh, 0, 0)),
                pl.BlockSpec((1, page, d), kv_index),
                pl.BlockSpec((1, page, d), kv_index),
            ],
            out_specs=pl.BlockSpec((1, rep, d),
                                   lambda bh, ip, bt, ln: (bh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, d), jnp.float32),
                pltpu.VMEM((rep,), jnp.float32),
                pltpu.VMEM((rep,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b * hkv, rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(bt, lens, qr, kr, vr)
    return out.reshape(b, hkv, rep, d).reshape(b, 1, h, d)
