"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar memory).

Block pattern per config (xLSTM-1.3b uses 7 mLSTM : 1 sLSTM).  d_ff = 0 —
each block carries its own up/down projections.

mLSTM is computed in **chunkwise-parallel form** for train/prefill — within a
chunk an attention-like masked product, across chunks an O(1) recurrent state
(C ∈ R^{dh×dh}, n ∈ R^{dh}, m ∈ R).  This is the TPU adaptation of the
paper's fused CUDA recurrent kernel: the chunkwise form turns the sequential
scan into MXU-friendly matmuls with a short lax.scan over chunks.  Decode is
the exact recurrent form — O(1) state, so `long_500k` runs natively.

sLSTM has genuine hidden-state feedback (h_{t-1} enters the gates) and cannot
be parallelized over time; it runs as a lax.scan with per-head block-diagonal
recurrent matrices, exactly as in the paper.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import runtime
from repro.models import dense
from repro.models.config import ModelConfig


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def group_structure(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.xlstm_pattern or ("m", "s")
    n_groups = cfg.n_layers // len(pat)
    tail = pat[: cfg.n_layers - n_groups * len(pat)]
    return n_groups, tail


def _ud(cfg: ModelConfig) -> int:
    return int(cfg.d_model * cfg.xlstm_up_factor)


# ------------------------------------------------------------------- params
def _mlstm_params(key, cfg: ModelConfig, dt) -> Dict:
    d, ud, H = cfg.d_model, _ud(cfg), cfg.n_heads
    dh = ud // H
    ks = jax.random.split(key, 10)
    blockdiag = lambda k: (jax.random.normal(k, (H, dh, dh), jnp.float32)
                           / dh ** 0.5).astype(dt)
    return {
        "ln": cm.norm_params(d, "rmsnorm", dt),
        "w_up": cm.dense_init(ks[0], d, ud, dt),
        "w_gate": cm.dense_init(ks[1], d, ud, dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, ud)) * 0.1
                   ).astype(dt),
        "conv_b": jnp.zeros((ud,), dt),
        "wq": blockdiag(ks[3]),
        "wk": blockdiag(ks[4]),
        "wv": blockdiag(ks[5]),
        "w_i": cm.dense_init(ks[6], ud, H, jnp.float32, scale=0.3),
        "w_f": cm.dense_init(ks[7], ud, H, jnp.float32, scale=0.3),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # forget bias: remember
        "w_down": cm.dense_init(ks[8], ud, d, dt),
    }


def _slstm_params(key, cfg: ModelConfig, dt) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 12)
    wx = lambda k: cm.dense_init(k, d, d, dt)
    rr = lambda k: (jax.random.normal(k, (H, dh, dh), jnp.float32)
                    / dh ** 0.5).astype(jnp.float32)
    fup = int(d * 4 / 3)
    return {
        "ln": cm.norm_params(d, "rmsnorm", dt),
        "w_z": wx(ks[0]), "r_z": rr(ks[1]),
        "w_i": wx(ks[2]), "r_i": rr(ks[3]),
        "w_f": wx(ks[4]), "r_f": rr(ks[5]),
        "w_o": wx(ks[6]), "r_o": rr(ks[7]),
        "b_f": jnp.full((d,), 3.0, jnp.float32),
        "w_up1": cm.dense_init(ks[8], d, fup, dt),
        "w_up2": cm.dense_init(ks[9], d, fup, dt),
        "w_down": cm.dense_init(ks[10], fup, d, dt),
    }


def _stack(fn, key, n: int):
    ks = jax.random.split(key, n)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn(k) for k in ks])


def init(key: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dt(cfg)
    pat = cfg.xlstm_pattern or ("m", "s")
    n_groups, tail = group_structure(cfg)
    keys = jax.random.split(key, 8)
    p: Dict = {
        "embed": cm.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": cm.norm_params(cfg.d_model, "rmsnorm", dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.dense_init(keys[5], cfg.d_model, cfg.padded_vocab, dt)
    group: Dict = {}
    for i, kind in enumerate(pat):
        sub = jax.random.fold_in(keys[1], i)
        mk = (functools.partial(_mlstm_params, cfg=cfg, dt=dt) if kind == "m"
              else functools.partial(_slstm_params, cfg=cfg, dt=dt))
        group[f"blk{i}"] = _stack(mk, sub, n_groups)
    p["groups"] = group
    tail_p: Dict = {}
    for i, kind in enumerate(tail):
        sub = jax.random.fold_in(keys[2], i)
        tail_p[f"blk{i}"] = (_mlstm_params(sub, cfg, dt) if kind == "m"
                             else _slstm_params(sub, cfg, dt))
    p["tail"] = tail_p
    return p


# ------------------------------------------------------------- mLSTM cell
def _mlstm_qkvif(mp: Dict, cfg: ModelConfig, x: jax.Array):
    """x: (B,T,d) -> q,k,v (B,T,H,dh) fp32; i,f pre-activations (B,T,H)."""
    b, t, _ = x.shape
    H = cfg.n_heads
    ud = _ud(cfg)
    dh = ud // H
    h = cm.apply_norm(x, mp["ln"], "rmsnorm")
    u = h @ mp["w_up"]
    g = h @ mp["w_gate"]
    cw = mp["conv_w"].shape[0]
    conv = jnp.zeros_like(u)
    for j in range(cw):
        shifted = jnp.pad(u, [(0, 0), (j, 0), (0, 0)])[:, :t]
        conv = conv + shifted * mp["conv_w"][j][None, None, :]
    conv = jax.nn.silu(conv + mp["conv_b"][None, None, :])
    ch = conv.reshape(b, t, H, dh).astype(jnp.float32)
    uh = u.reshape(b, t, H, dh).astype(jnp.float32)
    q = jnp.einsum("bthd,hde->bthe", ch, mp["wq"].astype(jnp.float32))
    k = jnp.einsum("bthd,hde->bthe", ch, mp["wk"].astype(jnp.float32)) / dh ** 0.5
    v = jnp.einsum("bthd,hde->bthe", uh, mp["wv"].astype(jnp.float32))
    it = conv.astype(jnp.float32) @ mp["w_i"]                    # (B,T,H)
    ft = conv.astype(jnp.float32) @ mp["w_f"] + mp["b_f"][None, None, :]
    return q, k, v, it, ft, g, u


def mlstm_chunkwise(q, k, v, it, ft, state=None, chunk: int = 64):
    """Chunkwise-parallel mLSTM. q,k,v: (B,T,H,dh); it,ft: (B,T,H).

    Returns (h (B,T,H,dh), final_state (C (B,H,dh,dh), n (B,H,dh), m (B,H))).
    """
    b, t, H, dh = q.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        z4 = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(a, z4) for a in (q, k, v))
        # padded steps must be identity updates: i = -inf (no write, and no
        # influence on the stabilizer), f -> 1 (no decay of the final state)
        it = jnp.pad(it, [(0, 0), (0, pad), (0, 0)], constant_values=-1e30)
        ft = jnp.pad(ft, [(0, 0), (0, pad), (0, 0)], constant_values=30.0)
    tp = t + pad
    nc = tp // chunk
    # (B, nc, c, H, dh) -> scan over nc
    rs = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = rs(q), rs(k), rs(v)
    ic, fc = rs(it), rs(ft)

    if state is None:
        C0 = jnp.zeros((b, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, H, dh), jnp.float32)
        m0 = jnp.full((b, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, xs):
        C, n, m = carry
        qj, kj, vj, ij, fj = xs               # (B,c,H,dh) / (B,c,H)
        logf = jax.nn.log_sigmoid(fj)         # (B,c,H)
        cumf = jnp.cumsum(logf, axis=1)       # (B,c,H)
        bb = ij - cumf                        # b_s = i_s - cumlogf_s
        M = jnp.maximum(jax.lax.cummax(bb, axis=1), m[:, None])   # (B,c,H)
        m_t = cumf + M
        # intra-chunk: w_ts = exp(b_s - M_t) for s <= t
        w = jnp.exp(bb[:, None, :, :] - M[:, :, None, :])         # (B,c_t,c_s,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(causal[None, :, :, None], w, 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qj, kj)            # (B,c,c,H)
        intra_num = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, vj)
        intra_den = jnp.einsum("btsh,btsh->bth", scores, w)
        # inter-chunk: decay from incoming state
        inter_scale = jnp.exp(m[:, None] - M)                     # (B,c,H)
        inter_num = jnp.einsum("bthd,bhde->bthe", qj, C) * inter_scale[..., None]
        inter_den = jnp.einsum("bthd,bhd->bth", qj, n) * inter_scale
        num = intra_num + inter_num
        den = intra_den + inter_den
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to the next chunk
        total_f = cumf[:, -1]                                     # (B,H)
        Mc = M[:, -1]                                             # (B,H)
        m_next = total_f + Mc
        sc = jnp.exp(ij - cumf + total_f[:, None] - m_next[:, None])  # (B,c,H)
        C_next = (C * jnp.exp(m + total_f - m_next)[..., None, None]
                  + jnp.einsum("bshd,bsh,bshe->bhde", kj, sc, vj))
        n_next = (n * jnp.exp(m + total_f - m_next)[..., None]
                  + jnp.einsum("bshd,bsh->bhd", kj, sc))
        return (C_next, n_next, m_next), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0),
                                 (qc, kc, vc, ic, fc),
                                 unroll=runtime.scan_unroll())
    h = hs.swapaxes(0, 1).reshape(b, tp, H, dh)[:, :t]
    return h, (C, n, m)


def mlstm_recurrent_step(q, k, v, it, ft, state):
    """Exact recurrent mLSTM step. q,k,v: (B,1,H,dh); it,ft: (B,1,H)."""
    C, n, m = state
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]                        # (B,H,dh)
    logf = jax.nn.log_sigmoid(ft[:, 0])                           # (B,H)
    i1 = it[:, 0]
    m_new = jnp.maximum(logf + m, i1)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(i1 - m_new)
    C = C * fp[..., None, None] + ip[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k1, v1)
    n = n * fp[..., None] + ip[..., None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, C)
    den = jnp.einsum("bhd,bhd->bh", q1, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h[:, None], (C, n, m_new)


def _mlstm_block(mp: Dict, cfg: ModelConfig, x: jax.Array, state=None,
                 conv_state=None, decode: bool = False, chunk: int = 64):
    b, t, d = x.shape
    ud = _ud(cfg)
    if decode:
        h_in = cm.apply_norm(x, mp["ln"], "rmsnorm")
        u = h_in @ mp["w_up"]
        g = h_in @ mp["w_gate"]
        hist = jnp.concatenate([conv_state, u], axis=1)           # (B,cw,ud)
        conv = (hist * mp["conv_w"][::-1][None]).sum(1, keepdims=True) \
            + mp["conv_b"][None, None, :]
        conv = jax.nn.silu(conv)
        H = cfg.n_heads
        dh = ud // H
        ch = conv.reshape(b, 1, H, dh).astype(jnp.float32)
        uh = u.reshape(b, 1, H, dh).astype(jnp.float32)
        q = jnp.einsum("bthd,hde->bthe", ch, mp["wq"].astype(jnp.float32))
        k = jnp.einsum("bthd,hde->bthe", ch, mp["wk"].astype(jnp.float32)) / dh ** 0.5
        v = jnp.einsum("bthd,hde->bthe", uh, mp["wv"].astype(jnp.float32))
        it = conv.astype(jnp.float32) @ mp["w_i"]
        ft = conv.astype(jnp.float32) @ mp["w_f"] + mp["b_f"][None, None, :]
        hseq, new_state = mlstm_recurrent_step(q, k, v, it, ft, state)
        new_conv = hist[:, 1:]
    else:
        q, k, v, it, ft, g, u = _mlstm_qkvif(mp, cfg, x)
        hseq, new_state = mlstm_chunkwise(q, k, v, it, ft, state, chunk)
        new_conv = u[:, -(cfg.conv_width - 1):]
    hflat = hseq.reshape(b, hseq.shape[1], ud).astype(x.dtype)
    out = (hflat * jax.nn.silu(g)) @ mp["w_down"]
    return x + out, new_state, new_conv


# ------------------------------------------------------------- sLSTM cell
def _slstm_block(sp: Dict, cfg: ModelConfig, x: jax.Array, state=None):
    """Sequential sLSTM.  x: (B,T,d).  state: (c, n, m, h) each (B,d)."""
    b, t, d = x.shape
    H = cfg.n_heads
    dh = d // H
    xin = cm.apply_norm(x, sp["ln"], "rmsnorm").astype(jnp.float32)
    # precompute input contributions for all t
    zx = xin @ sp["w_z"].astype(jnp.float32)
    ix = xin @ sp["w_i"].astype(jnp.float32)
    fx = xin @ sp["w_f"].astype(jnp.float32) + sp["b_f"][None, None, :]
    ox = xin @ sp["w_o"].astype(jnp.float32)
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = (zeros, zeros, jnp.full((b, d), -1e30, jnp.float32), zeros)

    rmat = {k: sp[k].astype(jnp.float32) for k in ("r_z", "r_i", "r_f", "r_o")}

    def rdot(r, h):
        hh = h.reshape(b, H, dh)
        return jnp.einsum("bhd,hde->bhe", hh, r).reshape(b, d)

    if runtime.roofline_mode() and t > 1:
        # FLOPs-equivalent parallel surrogate (see runtime.py): identical op
        # counts per timestep, h_{t-1} feedback replaced by the shifted input
        # stream so the T-step while-loop disappears from the HLO and
        # cost_analysis counts every timestep.  Values differ; counts don't.
        hprev = jnp.pad(zx, [(0, 0), (1, 0), (0, 0)])[:, :t]
        rdot_t = lambda r: jnp.einsum(
            "bthd,hde->bthe", hprev.reshape(b, t, H, dh), r).reshape(b, t, d)
        z = jnp.tanh(zx + rdot_t(rmat["r_z"]))
        i_pre = ix + rdot_t(rmat["r_i"])
        f_pre = fx + rdot_t(rmat["r_f"])
        o = jax.nn.sigmoid(ox + rdot_t(rmat["r_o"]))
        logf = jax.nn.log_sigmoid(f_pre)
        m_sur = jnp.maximum(jnp.cumsum(logf, 1), i_pre)
        fp, ip = jnp.exp(logf), jnp.exp(i_pre - m_sur)
        c_sur = jnp.cumsum(fp * z * ip, 1)
        n_sur = jnp.cumsum(fp * ip, 1)
        h = (o * c_sur / jnp.maximum(n_sur, 1.0)).astype(x.dtype)
        ff = (cm.gelu(h @ sp["w_up1"]) * (h @ sp["w_up2"])) @ sp["w_down"]
        state = (c_sur[:, -1], n_sur[:, -1], m_sur[:, -1], h[:, -1]
                 .astype(jnp.float32))
        return x + ff, state

    def step(carry, xs):
        c, n, m, h = carry
        zt, itt, ftt, ot = xs
        z = jnp.tanh(zt + rdot(rmat["r_z"], h))
        i_pre = itt + rdot(rmat["r_i"], h)
        f_pre = ftt + rdot(rmat["r_f"], h)
        o = jax.nn.sigmoid(ot + rdot(rmat["r_o"], h))
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(i_pre - m_new)
        c = fp * c + ip * z
        n = fp * n + ip
        h_new = o * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    xs = (zx.swapaxes(0, 1), ix.swapaxes(0, 1), fx.swapaxes(0, 1),
          ox.swapaxes(0, 1))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.swapaxes(0, 1).astype(x.dtype)                         # (B,T,d)
    # post-up-projection FFN (factor 4/3, gated)
    ff = (cm.gelu(h @ sp["w_up1"]) * (h @ sp["w_up2"])) @ sp["w_down"]
    return x + ff, state


# ------------------------------------------------------------------ forward
def _forward(params: Dict, cfg: ModelConfig, batch: Dict, want_cache: bool,
             chunk: int = 64):
    if runtime.roofline_mode():
        chunk = max(chunk, 1024)   # few, unrolled chunk-scan steps
    pat = cfg.xlstm_pattern or ("m", "s")
    _, tail = group_structure(cfg)
    x, _ = dense.embed_inputs(params, cfg, batch)
    s = x.shape[1]

    def run(x, bp, kind, st=None):
        if kind == "m":
            x, state, conv = _mlstm_block(bp, cfg, x, chunk=chunk)
            return x, {"C": state[0], "n": state[1], "m": state[2],
                       "conv": conv}
        x, state = _slstm_block(bp, cfg, x)
        return x, {"c": state[0], "n": state[1], "m": state[2],
                   "h": state[3]}

    def group_step(x, gp):
        states = {}
        for i, kind in enumerate(pat):
            x, st = run(x, gp[f"blk{i}"], kind)
            states[f"blk{i}"] = st
        return x, states

    body = jax.checkpoint(group_step)
    x, group_states = jax.lax.scan(body, x, params["groups"],
                                   unroll=runtime.scan_unroll())
    tail_states = []
    for i, kind in enumerate(tail):
        x, st = run(x, params["tail"][f"blk{i}"], kind)
        tail_states.append(st)
    x = cm.apply_norm(x, params["final_norm"], "rmsnorm")
    if want_cache:
        logits = dense.logits_of(params, cfg, x[:, -1:])
        return logits, {"groups": group_states, "tail": tail_states,
                        "length": jnp.asarray(s, jnp.int32)}
    return dense.logits_of(params, cfg, x), None


def apply(params: Dict, cfg: ModelConfig, batch: Dict, *,
          chunk: int = 64, **_) -> jax.Array:
    return _forward(params, cfg, batch, want_cache=False, chunk=chunk)[0]


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            chunk: int = 64, capacity: Optional[int] = None, **_):
    return _forward(params, cfg, batch, want_cache=True, chunk=chunk)


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, token: jax.Array):
    pat = cfg.xlstm_pattern or ("m", "s")
    _, tail = group_structure(cfg)
    x = jnp.take(params["embed"], token, axis=0)
    length = cache["length"]

    def run(x, bp, st, kind):
        if kind == "m":
            x, state, conv = _mlstm_block(
                bp, cfg, x, state=(st["C"], st["n"], st["m"]),
                conv_state=st["conv"], decode=True)
            return x, {"C": state[0], "n": state[1], "m": state[2],
                       "conv": conv}
        x, state = _slstm_block(bp, cfg, x,
                                state=(st["c"], st["n"], st["m"], st["h"]))
        return x, {"c": state[0], "n": state[1], "m": state[2],
                   "h": state[3]}

    def group_step(x, xs):
        gp, gst = xs
        new = {}
        for i, kind in enumerate(pat):
            x, st = run(x, gp[f"blk{i}"], gst[f"blk{i}"], kind)
            new[f"blk{i}"] = st
        return x, new

    x, new_groups = jax.lax.scan(group_step, x,
                                 (params["groups"], cache["groups"]),
                                 unroll=runtime.scan_unroll())
    new_tail = []
    for i, kind in enumerate(tail):
        x, st = run(x, params["tail"][f"blk{i}"], cache["tail"][i], kind)
        new_tail.append(st)
    x = cm.apply_norm(x, params["final_norm"], "rmsnorm")
    return dense.logits_of(params, cfg, x), {
        "groups": new_groups, "tail": new_tail, "length": length + 1}
