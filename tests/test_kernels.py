"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.flash_decode import flash_decode_tpu
from repro.kernels.paged_decode import flash_paged_decode_tpu
from repro.kernels.ref import (decode_ref, flash_ref, paged_decode_quant_ref,
                               paged_decode_ref, paged_verify_quant_ref,
                               paged_verify_ref, reference_attention,
                               verify_ref)
from repro.kernels.spec_verify import flash_paged_verify_tpu
from repro.kernels.tuning import (DEFAULT_TUNING, KernelTuning,
                                  autotune_paged_decode, clear_tunings,
                                  record_tuning, tuning_for)
from repro.models.attention import kv_quantize

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _qkv(key, b, sq, skv, h, hkv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32).astype(dtype)
    return q, k, v


PREFILL_SWEEP = [
    # (b, s, h, hkv, d, window, causal, bq, bk)
    (1, 128, 4, 4, 64, None, True, 64, 64),
    (2, 256, 8, 2, 64, None, True, 128, 128),
    (1, 192, 6, 1, 128, None, True, 64, 64),     # MQA, odd block count
    (2, 128, 4, 2, 32, 64, True, 32, 64),        # sliding window
    (1, 100, 4, 4, 64, None, True, 32, 32),      # non-multiple length
    (2, 64, 8, 8, 64, None, False, 32, 32),      # bidirectional (encoder)
]


@pytest.mark.parametrize("case", PREFILL_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(case, dtype):
    b, s, h, hkv, d, win, causal, bq, bk = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % 2**31), b, s, s, h, hkv,
                   d, dtype)
    ref = reference_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=causal,
                              window=win)
    out = flash_attention_tpu(q, k, v, causal=causal, window=win,
                              block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


DECODE_SWEEP = [
    # (b, s, h, hkv, d, cache_len, window, bk)
    (1, 512, 4, 4, 64, 512, None, 128),
    (2, 1024, 8, 2, 64, 700, None, 256),
    (4, 256, 4, 1, 128, 256, None, 64),
    (1, 300, 4, 2, 64, 123, None, 128),          # partial + non-multiple
    (2, 512, 8, 2, 64, 400, 128, 128),           # sliding window mask
]


@pytest.mark.parametrize("case", DECODE_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(case, dtype):
    b, s, h, hkv, d, clen, win, bk = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32).astype(dtype)
    cl = jnp.asarray(clen, jnp.int32)
    ref = decode_ref(q.astype(jnp.float32), kc.astype(jnp.float32),
                     vc.astype(jnp.float32), cl, window=win)
    out = flash_decode_tpu(q, kc, vc, cl, window=win, block_k=bk,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@given(b=st.integers(1, 3), s=st.sampled_from([64, 96, 160]),
       hkv=st.sampled_from([1, 2, 4]), rep=st.sampled_from([1, 2, 3]),
       d=st.sampled_from([32, 64]), causal=st.booleans())
@settings(max_examples=12, deadline=None)
def test_flash_prefill_property(b, s, hkv, rep, d, causal):
    """Property: Pallas kernel == naive reference on random GQA shapes."""
    h = hkv * rep
    q, k, v = _qkv(jax.random.PRNGKey(b * 1000 + s + h), b, s, s, h, hkv,
                   d, jnp.float32)
    ref = reference_attention(q, k, v, causal=causal)
    out = flash_attention_tpu(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


def _paged_case(key, b, h, hkv, d, page, n_pool, maxp, lengths, dtype):
    """Random pool + per-row block tables with distinct physical pages per
    row; table entries past a row's allocation point at the scratch page 0."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (n_pool, page, hkv, d),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (n_pool, page, hkv, d),
                           jnp.float32).astype(dtype)
    bt = np.zeros((b, maxp), np.int32)
    free = list(range(1, n_pool))
    for i, ln in enumerate(lengths):
        for j in range(-(-ln // page)):
            bt[i, j] = free.pop()
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths, jnp.int32)


PAGED_SWEEP = [
    # (b, h, hkv, d, page, lengths)
    (2, 4, 2, 64, 16, (40, 25)),
    (3, 8, 2, 64, 32, (64, 1, 90)),            # exact-page + single-token
    (1, 4, 1, 128, 16, (47,)),                 # MQA, partial last page
    (2, 4, 4, 32, 8, (0, 30)),                 # empty row rides along
]


@pytest.mark.parametrize("case", PAGED_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_paged_decode_sweep(case, dtype):
    b, h, hkv, d, page, lengths = case
    maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
    n_pool = 1 + sum(-(-ln // page) for ln in lengths)
    q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(hash(case) % 2**31),
                                    b, h, hkv, d, page, n_pool, maxp,
                                    lengths, dtype)
    ref = paged_decode_ref(q.astype(jnp.float32), kp.astype(jnp.float32),
                           vp.astype(jnp.float32), bt, ln)
    out = flash_paged_decode_tpu(q, kp, vp, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


def test_paged_oracle_matches_contiguous_decode():
    """Gathering a row's pages must reproduce contiguous decode attention
    exactly — the paged oracle is itself validated against decode_ref."""
    key = jax.random.PRNGKey(3)
    page, n_pool, maxp, ln = 16, 6, 4, 55
    q, kp, vp, bt, lens = _paged_case(key, 1, 4, 2, 64, page, n_pool, maxp,
                                      (ln,), jnp.float32)
    ref = paged_decode_ref(q, kp, vp, bt, lens)
    contiguous_k = kp[bt[0]].reshape(1, maxp * page, 2, 64)
    contiguous_v = vp[bt[0]].reshape(1, maxp * page, 2, 64)
    out = decode_ref(q, contiguous_k, contiguous_v, lens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-6)


@given(b=st.integers(1, 3), page=st.sampled_from([8, 16, 32]),
       hkv=st.sampled_from([1, 2]), rep=st.sampled_from([1, 2, 3]),
       d=st.sampled_from([32, 64]), seed=st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_flash_paged_decode_property(b, page, hkv, rep, d, seed):
    """Property: paged Pallas kernel == gather oracle for random block
    tables, page sizes, and per-row lengths (incl. empty rows)."""
    rng = np.random.default_rng(seed)
    lengths = tuple(int(x) for x in rng.integers(0, 4 * page, size=b))
    maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
    n_pool = 1 + sum(-(-ln // page) for ln in lengths)
    q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(seed), b, hkv * rep,
                                    hkv, d, page, n_pool, maxp, lengths,
                                    jnp.float32)
    ref = paged_decode_ref(q, kp, vp, bt, ln)
    out = flash_paged_decode_tpu(q, kp, vp, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


def _paged_verify_case(key, b, kq, h, hkv, d, page, lengths, dtype):
    """Random pool + block tables with pages covering ``lengths[i] + kq``
    tokens per row — the kq new tokens' KV is 'already scattered' (random
    data stands in for it); ``lengths`` is the valid count BEFORE them."""
    alloc = [ln + kq for ln in lengths]
    maxp = max(2, max(-(-a // page) for a in alloc) + 1)
    n_pool = 1 + sum(-(-a // page) for a in alloc)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, kq, h, d), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (n_pool, page, hkv, d),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (n_pool, page, hkv, d),
                           jnp.float32).astype(dtype)
    bt = np.zeros((b, maxp), np.int32)
    free = list(range(1, n_pool))
    for i, a in enumerate(alloc):
        for j in range(-(-a // page)):
            bt[i, j] = free.pop()
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths, jnp.int32)


VERIFY_SWEEP = [
    # (b, kq, h, hkv, d, page, lengths)
    (2, 4, 4, 2, 64, 16, (40, 25)),
    (1, 3, 4, 1, 128, 16, (47,)),              # MQA, partial last page
    (3, 2, 8, 2, 64, 32, (64, 1, 90)),         # exact-page + single-token
    (2, 5, 4, 4, 32, 8, (0, 30)),              # empty-prefix row
]


@pytest.mark.parametrize("case", VERIFY_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_paged_verify_sweep(case, dtype):
    b, kq, h, hkv, d, page, lengths = case
    q, kp, vp, bt, ln = _paged_verify_case(
        jax.random.PRNGKey(hash(case) % 2**31), b, kq, h, hkv, d, page,
        lengths, dtype)
    ref = paged_verify_ref(q.astype(jnp.float32), kp.astype(jnp.float32),
                           vp.astype(jnp.float32), bt, ln)
    out = flash_paged_verify_tpu(q, kp, vp, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


def test_verify_oracle_matches_reference_with_offset():
    """verify_attention's per-query causal bound == naive reference
    attention with a q_offset — the multi-token oracle is itself
    validated."""
    b, kq, h, hkv, d, ln = 2, 4, 4, 2, 64, 37
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    s = ln + kq + 5                      # trailing garbage must be masked
    q = jax.random.normal(ks[0], (b, kq, h, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    out = verify_ref(q, kc, vc, jnp.asarray([ln, ln], jnp.int32))
    ref = reference_attention(q, kc[:, :ln + kq], vc[:, :ln + kq],
                              causal=True, q_offset=ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_verify_k1_reduces_to_paged_decode():
    """With one query token the verify oracle is exactly the paged decode
    oracle at cache_len + 1 (the token's KV already written)."""
    q, kp, vp, bt, ln = _paged_verify_case(jax.random.PRNGKey(7), 2, 1, 4,
                                           2, 64, 16, (40, 25), jnp.float32)
    a = paged_verify_ref(q, kp, vp, bt, ln)
    b_ = paged_decode_ref(q, kp, vp, bt, ln + 1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


@given(b=st.integers(1, 3), kq=st.integers(1, 5),
       page=st.sampled_from([8, 16, 32]), hkv=st.sampled_from([1, 2]),
       rep=st.sampled_from([1, 2, 3]), d=st.sampled_from([32, 64]),
       seed=st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_flash_paged_verify_property(b, kq, page, hkv, rep, d, seed):
    """Property: multi-token verify Pallas kernel == gather oracle for
    random block tables, draft depths, page sizes, and per-row lengths
    (incl. empty-prefix rows)."""
    rng = np.random.default_rng(seed)
    lengths = tuple(int(x) for x in rng.integers(0, 4 * page, size=b))
    q, kp, vp, bt, ln = _paged_verify_case(jax.random.PRNGKey(seed), b, kq,
                                           hkv * rep, hkv, d, page, lengths,
                                           jnp.float32)
    ref = paged_verify_ref(q, kp, vp, bt, ln)
    out = flash_paged_verify_tpu(q, kp, vp, bt, ln, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


@pytest.mark.parametrize("pps", [1, 2, 3, 4])
def test_paged_decode_pages_per_step_sweep(pps):
    """The tunable pages-per-step batching must be output-invariant: every
    pps (including non-divisors of maxp, which exercise the scratch-page
    padding) matches the gather oracle."""
    case = (3, 8, 2, 64, 16, (40, 1, 90))
    b, h, hkv, d, page, lengths = case
    maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
    n_pool = 1 + sum(-(-ln // page) for ln in lengths)
    q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(11), b, h, hkv, d,
                                    page, n_pool, maxp, lengths, jnp.float32)
    ref = paged_decode_ref(q, kp, vp, bt, ln)
    out = flash_paged_decode_tpu(q, kp, vp, bt, ln, pages_per_step=pps,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


@pytest.mark.parametrize("pps", [1, 2, 3])
def test_paged_verify_pages_per_step_sweep(pps):
    case = (2, 4, 4, 2, 64, 16, (40, 25))
    b, kq, h, hkv, d, page, lengths = case
    q, kp, vp, bt, ln = _paged_verify_case(jax.random.PRNGKey(13), b, kq, h,
                                           hkv, d, page, lengths, jnp.float32)
    ref = paged_verify_ref(q, kp, vp, bt, ln)
    out = flash_paged_verify_tpu(q, kp, vp, bt, ln, pages_per_step=pps,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


def _quantize_pools(kp, vp):
    kq_, ks_ = kv_quantize(kp)
    vq_, vs_ = kv_quantize(vp)
    return kq_, vq_, ks_, vs_


@pytest.mark.parametrize("case", PAGED_SWEEP)
def test_paged_decode_quant_kernel_matches_quant_oracle(case):
    """In-kernel dequantize == gather-then-dequantize oracle (exact up to
    fp accumulation order) for the int8 paged decode kernel."""
    b, h, hkv, d, page, lengths = case
    maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
    n_pool = 1 + sum(-(-ln // page) for ln in lengths)
    q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(hash(case) % 2**31),
                                    b, h, hkv, d, page, n_pool, maxp,
                                    lengths, jnp.float32)
    kq_, vq_, ks_, vs_ = _quantize_pools(kp, vp)
    ref = paged_decode_quant_ref(q, kq_, vq_, ks_, vs_, bt, ln)
    out = flash_paged_decode_tpu(q, kq_, vq_, bt, ln, k_scale=ks_,
                                 v_scale=vs_, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


@pytest.mark.parametrize("case", PAGED_SWEEP)
def test_paged_decode_quant_tolerance_vs_fp(case):
    """Tolerance oracle: int8 pages reproduce the fp attention output
    within the quantization error budget (int8 per-token-per-head scales
    keep the relative element error ~< 1/127 ~ 0.8%)."""
    b, h, hkv, d, page, lengths = case
    maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
    n_pool = 1 + sum(-(-ln // page) for ln in lengths)
    q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(hash(case) % 2**31),
                                    b, h, hkv, d, page, n_pool, maxp,
                                    lengths, jnp.float32)
    kq_, vq_, ks_, vs_ = _quantize_pools(kp, vp)
    fp = paged_decode_ref(q, kp, vp, bt, ln)
    out = flash_paged_decode_tpu(q, kq_, vq_, bt, ln, k_scale=ks_,
                                 v_scale=vs_, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp), atol=0.12,
                               rtol=0.05)


@pytest.mark.parametrize("case", VERIFY_SWEEP)
def test_paged_verify_quant_kernel_matches_quant_oracle(case):
    b, kq, h, hkv, d, page, lengths = case
    q, kp, vp, bt, ln = _paged_verify_case(
        jax.random.PRNGKey(hash(case) % 2**31), b, kq, h, hkv, d, page,
        lengths, jnp.float32)
    kq_, vq_, ks_, vs_ = _quantize_pools(kp, vp)
    ref = paged_verify_quant_ref(q, kq_, vq_, ks_, vs_, bt, ln)
    out = flash_paged_verify_tpu(q, kq_, vq_, bt, ln, k_scale=ks_,
                                 v_scale=vs_, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


@given(b=st.integers(1, 2), page=st.sampled_from([8, 16]),
       hkv=st.sampled_from([1, 2]), rep=st.sampled_from([1, 2]),
       pps=st.sampled_from([1, 2, 3]), seed=st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_paged_decode_quant_property(b, page, hkv, rep, pps, seed):
    """Property: int8 kernel == int8 oracle across random tables, page
    sizes, lengths, AND pages-per-step (tuning must never change
    results, only speed)."""
    rng = np.random.default_rng(seed)
    lengths = tuple(int(x) for x in rng.integers(0, 4 * page, size=b))
    maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
    n_pool = 1 + sum(-(-ln // page) for ln in lengths)
    q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(seed), b, hkv * rep,
                                    hkv, 32, page, n_pool, maxp, lengths,
                                    jnp.float32)
    kq_, vq_, ks_, vs_ = _quantize_pools(kp, vp)
    ref = paged_decode_quant_ref(q, kq_, vq_, ks_, vs_, bt, ln)
    out = flash_paged_decode_tpu(q, kq_, vq_, bt, ln, k_scale=ks_,
                                 v_scale=vs_, pages_per_step=pps,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=1e-3)


def test_tuning_registry_roundtrip():
    clear_tunings()
    try:
        assert tuning_for(16, 64, 2) == DEFAULT_TUNING
        record_tuning(16, 64, 2, KernelTuning(pages_per_step=4))
        assert tuning_for(16, 64, 2).pages_per_step == 4
        assert tuning_for(32, 64, 2) == DEFAULT_TUNING   # other key untouched
    finally:
        clear_tunings()


def test_autotune_records_winner_and_kernel_uses_it():
    """autotune sweeps the candidates, records the fastest for the shape
    key, and the recorded choice feeds the kernel by default without
    changing its output."""
    clear_tunings()
    try:
        case = (2, 4, 2, 64, 16, (40, 25))
        b, h, hkv, d, page, lengths = case
        maxp = max(2, max(-(-ln // page) for ln in lengths) + 1)
        n_pool = 1 + sum(-(-ln // page) for ln in lengths)
        q, kp, vp, bt, ln = _paged_case(jax.random.PRNGKey(17), b, h, hkv,
                                        d, page, n_pool, maxp, lengths,
                                        jnp.float32)
        t = autotune_paged_decode(q, kp, vp, bt, ln, candidates=(1, 2),
                                  iters=1)
        assert t.pages_per_step in (1, 2)
        assert tuning_for(page, d, hkv) == t
        ref = paged_decode_ref(q, kp, vp, bt, ln)
        out = flash_paged_decode_tpu(q, kp, vp, bt, ln, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=1e-3)
    finally:
        clear_tunings()


def test_jnp_flash_is_its_own_oracle():
    """flash_ref (chunked) == reference (naive) — the oracle is validated."""
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 160, 160, 6, 2, 64, jnp.float32)
    a = flash_ref(q, k, v, causal=True, q_chunk=64, kv_chunk=32)
    b_ = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)
