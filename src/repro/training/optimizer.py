"""AdamW in pure JAX (no optax): decoupled weight decay + bias correction."""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.minimum(warm, 1.0) * decay


def init_opt_state(params) -> Dict[str, Any]:
    # fp32 first/second moments regardless of param dtype
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        p32 = p.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        decay = cfg.weight_decay * p32 if p.ndim >= 2 else 0.0
        p32 = p32 - lr * (update + decay)
        return p32.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    return (jax.tree.unflatten(tdef, new_p),
            {"mu": jax.tree.unflatten(tdef, new_mu),
             "nu": jax.tree.unflatten(tdef, new_nu), "step": step},
            {"grad_norm": gnorm, "lr": lr})
