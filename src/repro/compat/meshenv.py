"""Mesh-environment abstraction: one API over JAX >=0.5 and JAX 0.4.x.

The model stack targets the modern mesh-context API
(``jax.sharding.get_abstract_mesh`` / ``AxisType`` / ``set_mesh``); older
installs (0.4.x, as shipped in the offline container) expose none of those
and instead track the ambient mesh through the ``with mesh:`` thread-local
(``jax._src.mesh.thread_resources``).  Everything below dispatches on what
the installed ``jax.sharding`` actually provides — detected per call, so
tests can monkeypatch either API surface — and returns ``None`` / no-ops
when no mesh is active, which is the common single-device test path.

Public surface (the only sanctioned mesh introspection in this repo):

* ``make_mesh(shape, axis_names)``        — version-portable mesh builder
* ``current_mesh()``                      — active mesh or ``None``
* ``axis_names()`` / ``axis_sizes()``     — ambient-mesh introspection
* ``mesh_size(mesh, axes)``               — product of named axis extents
* ``mesh_context(mesh)``                  — portable ``set_mesh``/``with m:``
* ``with_sharding_constraint(x, spec)``   — ambient-mesh constraint
* ``shard_map(f, mesh=..., ...)``         — portable shard_map import
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


def modern_api() -> bool:
    """True when the installed jax.sharding exposes the >=0.5 mesh API."""
    return hasattr(jax.sharding, "get_abstract_mesh")


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    JAX >=0.5 wants ``axis_types`` spelled out (future default is
    ``Explicit``); 0.4.x predates the kwarg entirely.
    """
    kwargs: Dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names), **kwargs)
        except TypeError:  # AxisType present but make_mesh predates kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


# ---------------------------------------------------------------------------
# ambient-mesh discovery
# ---------------------------------------------------------------------------

def _legacy_ambient_mesh() -> Optional[Mesh]:
    """0.4.x: the ``with mesh:`` context lives in mesh_lib.thread_resources."""
    try:
        from jax._src import mesh as mesh_lib
        phys = mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # noqa: BLE001 — internal layout moved; treat as bare
        return None
    return None if phys.empty else phys


def current_mesh():
    """The active mesh — abstract on modern JAX, concrete on 0.4.x — or
    ``None`` when no mesh context is in effect.

    The modern probe falls back to the legacy thread-local when it comes up
    empty, so a mesh entered via ``with mesh:`` (the only entry point on
    builds that expose ``get_abstract_mesh`` but not ``set_mesh``) is still
    discovered — ``mesh_context`` and ``current_mesh`` agree by
    construction in every API window.
    """
    if modern_api():
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not getattr(m, "empty", False) and m.axis_names:
            return m
    return _legacy_ambient_mesh()


def axis_names() -> Tuple[str, ...]:
    """Axis names of the active mesh (``()`` when unmeshed)."""
    m = current_mesh()
    if m is None:
        return ()
    try:
        return tuple(m.axis_names)
    except Exception:  # noqa: BLE001 — half-constructed mock meshes in tests
        return ()


def axis_sizes(mesh=None) -> Dict[str, int]:
    """``{axis_name: extent}`` for ``mesh`` (default: the active mesh)."""
    m = current_mesh() if mesh is None else mesh
    if m is None:
        return {}
    return dict(m.shape)


def mesh_size(mesh, axes: Axes) -> int:
    """Product of the named axis extents (1 for ``None`` / absent mesh)."""
    if mesh is None or axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    sizes = axis_sizes(mesh)
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


# ---------------------------------------------------------------------------
# mesh context + sharding application
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Enter ``mesh`` as the ambient mesh, whatever the JAX version.

    Modern JAX: ``jax.sharding.use_mesh`` (always a context manager) is
    preferred; ``set_mesh`` is tried next but only if its return value
    actually supports the context-manager protocol (in some versions it is
    a plain global setter).  Everything else — including 0.4.x, where the
    Mesh object is itself the context manager — falls back to
    ``with mesh:``, which ``current_mesh`` can always discover via its
    legacy thread-local probe.
    """
    if modern_api():
        use = getattr(jax.sharding, "use_mesh", None)
        if use is not None:
            with use(mesh):
                yield mesh
            return
        set_m = getattr(jax.sharding, "set_mesh", None)
        if set_m is not None:
            ctx = set_m(mesh)
            if hasattr(ctx, "__enter__"):
                with ctx:
                    yield mesh
                return
            # plain setter variant: the mesh is now set globally; restore
            # the previous one (its return value, when it is a mesh) after
            prev = ctx if ctx is not None else None
            try:
                yield mesh
            finally:
                set_m(prev)
            return
    with mesh:
        yield mesh


def with_sharding_constraint(x: jax.Array, spec: P) -> jax.Array:
    """Constrain ``x`` to ``spec`` under the ambient mesh (no-op unmeshed).

    On 0.4.x a bare PartitionSpec is only accepted inside the mesh context
    manager; binding the concrete mesh into a NamedSharding is valid in both
    worlds, so do that whenever the active mesh is concrete.
    """
    m = current_mesh()
    if m is None:
        return x
    if isinstance(m, Mesh):
        return jax.lax.with_sharding_constraint(x, NamedSharding(m, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    """Portable shard_map: jax.experimental on <=0.6, jax.shard_map after."""
    try:
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep)
    except ImportError:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_rep)
