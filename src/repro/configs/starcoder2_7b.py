"""StarCoder2-7B [arXiv:2402.19173] — dense, GQA kv=4, RoPE, LayerNorm+GeLU+bias."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    norm_type="layernorm",
    act="gelu",
    use_bias=True,
    rope_theta=1e5,
)
