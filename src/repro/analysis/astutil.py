"""Small shared AST helpers for the checkers (stdlib ``ast`` only)."""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, None for anything dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of a call target (``functools.partial``, ``print``)."""
    return dotted(call.func)


def imported_modules(tree: ast.Module) -> Iterator[Tuple[str, int]]:
    """(module_name, lineno) for every import, wherever it appears.

    ``from x import y`` yields ``x`` AND ``x.y`` — ``y`` may be a
    submodule, and layering rules must see that edge either way.
    """
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                yield alias.name, node.lineno
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            yield node.module, node.lineno
            for alias in node.names:
                if alias.name != "*":
                    yield f"{node.module}.{alias.name}", node.lineno


def numpy_aliases(tree: ast.Module) -> Set[str]:
    """Local names bound to the host ``numpy`` module (not jax.numpy)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "numpy":
                    out.add(alias.asname or "numpy")
    return out


def assigned_names(node: ast.AST) -> Set[str]:
    """Names bound anywhere inside ``node`` (assignments, loops, with,
    imports, nested defs) — a conservative local-scope approximation."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store,
                                                          ast.Del)):
            out.add(n.id)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            out.add(n.name)
        elif isinstance(n, (ast.Import, ast.ImportFrom)):
            for alias in n.names:
                out.add((alias.asname or alias.name).split(".")[0])
    return out


def param_names(fn) -> Set[str]:
    """All parameter names of a FunctionDef/Lambda."""
    a = fn.args
    params = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    if a.vararg:
        params.append(a.vararg)
    if a.kwarg:
        params.append(a.kwarg)
    return {p.arg for p in params}


def module_scope_names(tree: ast.Module) -> Set[str]:
    """Names defined at module top level (defs, classes, imports,
    assignments) — what a module-level function may reference freely."""
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                out.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name):
                        out.add(n.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            out.add(node.target.id)
    return out


def const_literal(node: ast.AST):
    """(True, value) when ``node`` is a numeric/str/bool literal (allowing
    unary +/-), else (False, None)."""
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub,
                                                              ast.UAdd)):
        ok, v = const_literal(node.operand)
        if ok and isinstance(v, (int, float, complex)):
            return True, -v if isinstance(node.op, ast.USub) else v
        return False, None
    if isinstance(node, ast.Constant) and isinstance(
            node.value, (int, float, complex, str, bool)):
        return True, node.value
    return False, None


class FunctionIndex:
    """Functions of one module, addressable by name, with enclosing-scope
    info: module-level defs plus defs nested one level inside them."""

    def __init__(self, tree: ast.Module) -> None:
        self.module_level: Dict[str, ast.FunctionDef] = {}
        self.parent: Dict[ast.FunctionDef, Optional[ast.FunctionDef]] = {}
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                self.module_level[node.name] = node
                self.parent[node] = None
                for inner in ast.walk(node):
                    if isinstance(inner, ast.FunctionDef) and inner is not node:
                        self.parent.setdefault(inner, node)
