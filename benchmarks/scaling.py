"""Scale-out bench: gossip-digest vs probe-based routing at 100 / 1k / 10k
sim nodes (DESIGN.md §6.2-gossip; ROADMAP item 1).

A small hot minority of nodes is driven far past its capacity and must
offload into a large pool that carries moderate background traffic of its
own.  Both routing flavors share the identical gossip membership plane —
the only difference is how an origin picks the delegate:

* ``probe``  — PoS-sample candidates and probe each one's live load until
  one accepts (the pre-gossip behavior; 2 messages per probe).  The bench
  runs it with power-of-two choice — the strongest probe configuration
  (each round probes two stake-weighted candidates and keeps the
  phase-better one) — so the SLO bar gossip must match is the best the
  probe plane achieves, at that plane's true message cost.
* ``gossip`` — rank the local stale-digest table, dispatch to a
  stake-weighted pick among the near-tied leaders, probe only contended
  near-ties.

Reported per point and mode: SLO attainment, p95 latency, and routing
messages-per-request (probes x2 + dispatches + bounces over completed user
requests) plus the gossip-plane message count for context.  The 100- and
1k-node points feed the schema-7 ``gossip`` section of
``BENCH_scheduling.json``; the 10k point runs behind ``-m slow``
(``tests/test_scaling.py``) with partial views (``view_cap``), where full
O(n) membership per node stops being realistic.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import BackendProfile, WorkloadSpec
from repro.sim.servicemodel import DIGEST_INTERVAL_S
from repro.sim.workload import ArrivalPhase, make_requests

# Small commodity nodes whose KV budget binds before the compute knee, so
# occupancy is visible in the digest's headroom fields (kv budget of ~5
# typical requests at the workload's 128-prompt/192-output means).
_PROFILE = BackendProfile(prefill_tps=1e4, decode_tps=30.0, saturation=4,
                          max_concurrency=16, quality=0.5,
                          kv_token_budget=2048)

# (hot nodes, hot 1/lambda, background 1/lambda, t_end, gossip interval,
#  view cap) per pool size
SCALE_POINTS: Dict[int, Dict] = {
    100: dict(hot=8, hot_ia=1.0, bg_ia=16.0, t_end=40.0,
              gossip_interval=DIGEST_INTERVAL_S, view_cap=None),
    1000: dict(hot=32, hot_ia=1.0, bg_ia=16.0, t_end=40.0,
               gossip_interval=2.0, view_cap=128),
    10000: dict(hot=64, hot_ia=1.0, bg_ia=64.0, t_end=20.0,
                gossip_interval=4.0, view_cap=64),
}
SLO_S = 60.0


def build_scale_network(n_nodes: int, routing: str, seed: int = 0,
                        point: Optional[Dict] = None):
    """A ``Network`` of ``n_nodes`` identical commodity nodes plus the
    hot/background workload specs for it."""
    p = point or SCALE_POINTS[n_nodes]
    net = Network(mode="decentralized", routing=routing, seed=seed,
                  ledger_mode="shared", duel=DuelParams(p_d=0.0, k_judges=0),
                  gossip_interval=p["gossip_interval"],
                  suspect_after=1e9,            # no churn at these points
                  restake_interval=None, init_balance=100.0,
                  power_of_two=(routing == "probe"))
    specs: List[WorkloadSpec] = []
    for i in range(n_nodes):
        nid = f"n{i:05d}"
        net.add_node(Node(nid, _PROFILE, policy=NodePolicy(),
                          view_cap=p["view_cap"]))
        ia = p["hot_ia"] if i < p["hot"] else p["bg_ia"]
        specs.append(WorkloadSpec(
            nid, [ArrivalPhase(0.0, p["t_end"], ia)],
            prompt_mean=128, output_mean=192, max_tokens=512, slo_s=SLO_S))
    return net, specs


def run_scale_point(n_nodes: int, routing: str, seed: int = 0,
                    point: Optional[Dict] = None) -> Dict:
    p = point or SCALE_POINTS[n_nodes]
    net, specs = build_scale_network(n_nodes, routing, seed=seed, point=p)
    reqs = make_requests(specs, seed=42 + seed)
    t0 = time.perf_counter()
    m = net.run(reqs, until=p["t_end"], trace_interval=None)
    wall = time.perf_counter() - t0
    n_user = len([c for c in m.completed if not c.is_duel_extra])
    return {
        "slo_attainment": round(m.slo_attainment(), 4),
        "p95_latency_s": round(m.latency_percentile(95), 2),
        "routing_msgs_per_req": round(
            net.routing_messages / max(1, n_user), 3),
        "gossip_msgs": net.msg_counts["gossip"],
        "probes": net.msg_counts["probe"],
        "dispatches": net.msg_counts["dispatch"],
        "bounces": net.msg_counts["bounce"],
        "delegation_rate": round(m.delegation_rate(), 3),
        "n": n_user,
        "n_submitted": len(reqs),
        "wall_s": round(wall, 2),
    }


def gossip_scaling_section(seed: int = 0) -> Dict:
    """The schema-7 ``gossip`` payload section: 100- and 1k-node points,
    gossip vs probe routing (the 10k point stays behind ``-m slow``)."""
    points: Dict[str, Dict] = {}
    for n_nodes in (100, 1000):
        modes = {r: run_scale_point(n_nodes, r, seed=seed)
                 for r in ("gossip", "probe")}
        g, pb = modes["gossip"], modes["probe"]
        points[str(n_nodes)] = {
            **modes,
            "msgs_ratio": round(pb["routing_msgs_per_req"]
                                / max(1e-9, g["routing_msgs_per_req"]), 2),
            "slo_gap": round(abs(g["slo_attainment"]
                                 - pb["slo_attainment"]), 4),
        }
    return {"workload": "hot-minority offload into moderate background pool",
            "slo_s": SLO_S, "points": points}


def main(rows: List[str]) -> None:
    for n_nodes in (100, 1000):
        for routing in ("gossip", "probe"):
            r = run_scale_point(n_nodes, routing)
            rows.append(
                f"scaling_{n_nodes}_{routing},{r['wall_s'] * 1e6:.0f},"
                f"slo={r['slo_attainment']:.3f};p95={r['p95_latency_s']:.1f};"
                f"msgs_per_req={r['routing_msgs_per_req']:.2f};"
                f"gossip_msgs={r['gossip_msgs']};n={r['n']}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
