"""Train a small dense model for a few hundred steps on CPU.

Exercises the full training substrate (data pipeline -> model -> AdamW ->
checkpoint) and asserts the loss actually drops.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.training import AdamWConfig, init_state, make_train_step
from repro.training import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke().replace(dtype="float32")
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    state = init_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")
    step_fn = jax.jit(make_train_step(cfg, opt, q_chunk=64, kv_chunk=64))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                    global_batch=8, seed=0))
    first = None
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
        state, m = step_fn(state, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={loss:.4f}")
    assert loss < first - 0.5, "training did not reduce loss"
    with tempfile.NamedTemporaryFile(suffix=".msgpack") as f:
        ckpt.save(f.name, state, step=args.steps)
        _, step = ckpt.restore(f.name, state)
        print(f"checkpoint roundtrip OK at step {step}; "
              f"loss {first:.3f} -> {loss:.3f}")


if __name__ == "__main__":
    main()
