"""Pluggable request-execution backends (the node's Model Manager core).

The paper's nodes run vLLM/SGLang-style continuous-batching engines, so the
latency a request sees depends on the *time-varying* batch it shares the
accelerator with — not on a share frozen at admission.  This module defines
the Executor contract both backends implement (DESIGN.md §6.1):

* ``Executor``            — ``admit(item) -> bool`` (KV-budget gated),
                            progress driven by events or steps, a ``load()``
                            snapshot, and a completion callback that carries
                            start/first-token times (TTFT, queue wait).
* ``TokenBucketExecutor`` — the simulated backend: token-level prefill then
                            decode progress integrated piecewise-linearly by
                            the ``EventLoop``, with the decode share
                            recomputed on every membership change and
                            admission gated by a KV *token* budget rather
                            than a stream count.  At steady state (constant
                            occupancy) it reproduces the analytic
                            ``BackendProfile.service_time`` exactly; under
                            bursts and churn, in-flight requests slow down
                            and speed up as the batch shifts.  With
                            ``page_size`` set, admission switches to the
                            page-granularity rule shared with the real
                            paged engine (``paged_admit_ok``): prompt pages
                            must fit the free pool, decode pages accrue
                            with generation progress.  The sim does not
                            model preemption — transient over-occupancy
                            simply shows up as zero page headroom.

The real-engine counterpart (``EngineExecutor``, slot-based continuous
batching over the JAX ``Engine``) lives in ``repro.serving.executor``.

This module (plus ``servicemodel``) is the only sanctioned caller of
``BackendProfile.service_time`` — a grep-guard in ``tests/test_compat.py``
keeps frozen-share scheduling from creeping back in.
"""

from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Any, Callable, List, Optional

from repro.sim.events import EventLoop
from repro.sim.servicemodel import KV_TOKENS_PER_STREAM, BackendProfile

# completion callback: (item, started_at, first_token_at) in sim/wall time
CompletionFn = Callable[[Any, float, float], None]

# token-progress slack absorbing float error in rate*dt integration: 1e-6
# tokens is ~1e-8 s of decode — far below any latency we report
_EPS = 1e-6


def pages_for(tokens: int, page_size: int) -> int:
    """KV pages needed to hold ``tokens`` (every sequence owns >= 1 page)."""
    return max(1, -(-int(tokens) // int(page_size)))


def paged_admit_ok(free_pages: int, prompt_tokens: int, page_size: int,
                   resident: bool) -> bool:
    """THE paged admission rule, shared by the simulated and real backends
    (DESIGN.md §6.1, paged backend): a request is admitted when its
    *prompt* pages fit the free pool — its decode pages are claimed one at
    a time as it generates (preempt-and-requeue reclaims them under
    pressure).  An empty backend always admits one request so oversized
    prompts cannot deadlock the queue.
    """
    return (not resident) or pages_for(prompt_tokens, page_size) <= free_pages


@dataclass(frozen=True)
class ExecutorLoad:
    """Point-in-time snapshot of an executor's occupancy.

    ``active_streams`` are requests holding compute now; ``queued_streams``
    are admitted but waiting for a slot (real engine only).  Token counts
    are *remaining* work; ``kv_used``/``kv_budget`` express KV-memory
    pressure in tokens.  Paged backends additionally report page-pool
    occupancy (``pages_total`` stays 0 for contiguous backends).
    """

    active_streams: int
    queued_streams: int
    pending_prefill_tokens: int
    pending_decode_tokens: int
    kv_used: int
    kv_budget: int
    pages_used: int = 0
    pages_total: int = 0

    @property
    def kv_headroom(self) -> float:
        """Free fraction of the KV budget, in [0, 1]."""
        if self.kv_budget <= 0:
            return 1.0
        return max(0.0, 1.0 - self.kv_used / self.kv_budget)

    @property
    def page_headroom(self) -> float:
        """Free fraction of the KV page pool, in [0, 1] (1.0 = unpaged)."""
        if self.pages_total <= 0:
            return 1.0
        return max(0.0, 1.0 - self.pages_used / self.pages_total)


class Executor(ABC):
    """Backend-agnostic execution contract held by a Node's Model Manager."""

    def bind(self, loop: Optional[EventLoop], on_complete: CompletionFn) -> None:
        """Attach the driving clock and the completion callback."""
        self._loop = loop
        self._on_complete = on_complete

    @property
    @abstractmethod
    def n_active(self) -> int:
        """Number of streams currently holding compute."""

    @abstractmethod
    def admit(self, item: Any) -> bool:
        """Start executing ``item`` if KV headroom allows; False = try later."""

    @abstractmethod
    def load(self) -> ExecutorLoad:
        """Snapshot of current occupancy (routing / probing / rebalance)."""

    @abstractmethod
    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        """Expected service seconds for a hypothetical request admitted now."""


class _Stream:
    """One in-flight request inside the TokenBucketExecutor."""

    __slots__ = ("item", "prompt_left", "output_left", "prompt_total",
                 "output_total", "kv_tokens", "decoding", "started_at",
                 "first_token_at")

    def __init__(self, item: Any, prompt: int, output: int, now: float) -> None:
        self.item = item
        self.prompt_total = max(1, prompt)
        self.output_total = max(1, output)
        self.prompt_left = float(self.prompt_total)
        self.output_left = float(self.output_total)
        self.kv_tokens = self.prompt_total + self.output_total
        self.decoding = False
        self.started_at = now
        self.first_token_at: Optional[float] = None

    def tokens_held(self) -> int:
        """KV tokens this stream physically occupies right now (prompt plus
        decoded-so-far) — what a paged pool charges, vs the reserved
        ``kv_tokens`` a contiguous allocation charges up front."""
        if not self.decoding:
            return self.prompt_total
        decoded = self.output_total - max(0.0, self.output_left)
        return self.prompt_total + int(decoded)


class TokenBucketExecutor(Executor):
    """Simulated continuous batching: exact event-driven token integration.

    Between membership changes every stream progresses linearly (prefill at
    ``prefill_tps`` unshared, decode at ``decode_tps / share`` with
    ``share = max(1, n_active / saturation)``), so it suffices to advance
    all streams to ``now`` and re-derive the next phase boundary whenever
    the batch changes — no fixed tick quantum, no drift.
    """

    def __init__(self, profile: BackendProfile,
                 page_size: Optional[int] = None) -> None:
        self.profile = profile
        self.kv_budget = int(getattr(profile, "kv_token_budget", 0)
                             or profile.max_concurrency * KV_TOKENS_PER_STREAM)
        # page-granularity admission mode: the same KV budget expressed as a
        # pool of fixed-size pages, admitted on *prompt* pages only
        # (paged_admit_ok) — decode pages accrue as streams generate, so
        # admission matches the real paged engine's notion of "full"
        self.page_size = page_size
        self.pages_total = (self.kv_budget // page_size) if page_size else 0
        self._streams: List[_Stream] = []
        self._last_t = 0.0
        self._pending_ev = None
        self._loop: Optional[EventLoop] = None
        self._on_complete: Optional[CompletionFn] = None

    # ------------------------------------------------------------- interface
    @property
    def n_active(self) -> int:
        return len(self._streams)

    def _pages_used(self) -> int:
        return sum(pages_for(s.tokens_held(), self.page_size)
                   for s in self._streams)

    def admit(self, item: Any) -> bool:
        qr = item
        if self.page_size:
            self._advance()          # page holdings grow with decode progress
            free = self.pages_total - self._pages_used()
            if not paged_admit_ok(free, qr.req.prompt_tokens, self.page_size,
                                  resident=bool(self._streams)):
                return False
        else:
            kv = max(1, qr.req.prompt_tokens) + max(1, qr.req.output_tokens)
            used = sum(s.kv_tokens for s in self._streams)
            # token-budget admission; an empty backend always takes one
            # request so oversized prompts cannot deadlock the queue
            if self._streams and used + kv > self.kv_budget:
                return False
        self._advance()
        self._streams.append(_Stream(qr, qr.req.prompt_tokens,
                                     qr.req.output_tokens, self._loop.now))
        self._reschedule()
        return True

    def load(self) -> ExecutorLoad:
        self._advance()
        if self.page_size:
            pages_used = self._pages_used()
            kv_used = pages_used * self.page_size
            kv_budget = self.pages_total * self.page_size
        else:
            pages_used = 0
            kv_used = sum(s.kv_tokens for s in self._streams)
            kv_budget = self.kv_budget
        return ExecutorLoad(
            active_streams=len(self._streams),
            queued_streams=0,
            pending_prefill_tokens=int(sum(s.prompt_left
                                           for s in self._streams
                                           if not s.decoding)),
            pending_decode_tokens=int(sum(s.output_left
                                          for s in self._streams)),
            kv_used=kv_used,
            kv_budget=kv_budget,
            pages_used=pages_used,
            pages_total=self.pages_total)

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        return self.profile.service_time(prompt_tokens, output_tokens,
                                         len(self._streams) + 1)

    # -------------------------------------------------------------- dynamics
    def _decode_rate(self) -> float:
        share = max(1.0, len(self._streams) / self.profile.saturation)
        return self.profile.decode_tps / share

    def _rate(self, s: _Stream, decode_rate: float) -> float:
        return decode_rate if s.decoding else self.profile.prefill_tps

    def _advance(self) -> None:
        """Integrate token progress from the last update to ``now``."""
        now = self._loop.now
        dt = now - self._last_t
        self._last_t = now
        if dt <= 0.0 or not self._streams:
            return
        dec = self._decode_rate()
        for s in self._streams:
            if s.decoding:
                s.output_left -= dec * dt
            else:
                s.prompt_left -= self.profile.prefill_tps * dt

    def _reschedule(self) -> None:
        """Re-derive the earliest phase boundary and point one event at it.

        Called after every membership change; also flips streams whose
        boundary is (numerically) now, firing completions.
        """
        done: List[_Stream] = []
        for s in self._streams:
            if not s.decoding and s.prompt_left <= _EPS:
                s.decoding = True
                s.prompt_left = 0.0
                s.first_token_at = self._loop.now
            if s.decoding and s.output_left <= _EPS:
                done.append(s)
        if done:
            for s in done:
                self._streams.remove(s)
        if self._pending_ev is not None:
            self._loop.cancel(self._pending_ev)
            self._pending_ev = None
        if self._streams:
            dec = self._decode_rate()
            dt = min((s.output_left if s.decoding else s.prompt_left)
                     / self._rate(s, dec) for s in self._streams)
            self._pending_ev = self._loop.schedule(max(0.0, dt),
                                                   self._on_boundary)
        # completions fire after the reschedule: the callback may re-enter
        # admit() (node pulls the next queued request) and reschedule again
        for s in done:
            self._on_complete(s.item, s.started_at,
                              s.first_token_at or self._loop.now)

    def _on_boundary(self) -> None:
        self._pending_ev = None
        self._advance()
        self._reschedule()
