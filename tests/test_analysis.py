"""repro.analysis: the invariant linter, tested on fixture repos + live.

Two layers:

1.  Per-checker fixture tests — each rule gets a seeded tmp_path repo
    with a positive case (the violation fires), a negative case (the
    idiomatic form stays silent), plus shared suppression and
    baseline-round-trip mechanics.  The fixtures are also what the CLI
    exit-code test seeds, so ``python -m repro.analysis`` failing on a
    seeded violation is asserted per rule.
2.  The live pass (tier-1 acceptance, DESIGN.md §7): the full analyzer
    over this repository's src/ + tests/ + benchmarks/ must come back
    with zero NEW findings in under 10s, and the committed baseline must
    be empty — violations get fixed or carry an inline
    ``# repro: allow[...]`` justification, they do not accumulate.
"""

import json
import pathlib
import subprocess
import sys
import textwrap

from repro.analysis import (BASELINE_FILE, all_checkers, load_baseline,
                            run_analysis, save_baseline)
from repro.analysis.framework import Finding, RepoIndex, rule_matches

REPO = pathlib.Path(__file__).resolve().parents[1]

ALL_RULES = ("compat-boundary", "docs-anchors", "kernel-lint", "layering",
             "obs-lint", "twin-drift")


def mk_repo(tmp_path, files):
    for rel, content in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(content))
    return tmp_path


def analyze(root, rule=None):
    report = run_analysis(root, rules=[rule] if rule else None,
                          baseline_path="")
    return report


def rule_ids(report):
    return [f.rule_id for f in report.new]


# the DESIGN.md skeleton fixtures share: defines every pinned anchor
DESIGN_OK = """\
    # DESIGN
    ## §6.1 Executors
    ### §6.1-paged Paged
    ### §6.1-disagg Disagg
    ### §6.1-prefix Prefix cache
    ### §6.1-spec Spec
    ## §Perf-kernels Speed
    ## §6.2 Duels
    ### §6.2-gossip Load dissemination
    ## §6.3 Ledger
    ## §7 Analysis
    ## §Arch-applicability
    ## §Observability
"""
MD_STUBS = {"DESIGN.md": DESIGN_OK, "ROADMAP.md": "roadmap\n",
            "CHANGES.md": "changes\n", "README.md": "readme\n"}

# per-rule seeded violations; each MUST produce >= 1 finding of its rule
# (the CLI test below runs python -m repro.analysis against each of these)
SEEDED = {
    "compat-boundary": {
        **MD_STUBS,
        "src/repro/serving/x.py": """\
            from jax.sharding import use_mesh

            def f(m):
                with use_mesh(m):
                    return 1
        """,
    },
    "layering": {
        **MD_STUBS,
        "src/repro/core/x.py": """\
            from repro.serving.engine import Engine
        """,
    },
    "kernel-lint": {
        **MD_STUBS,
        "src/repro/kernels/x.py": """\
            import functools
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, *, b):
                print(x_ref)
                o_ref[...] = x_ref[...]

            def run(x, b):
                kernel = functools.partial(_k, b=b)
                return pl.pallas_call(kernel, grid=(x.shape[0] // b,))(x)
        """,
    },
    "twin-drift": {
        **MD_STUBS,
        "src/repro/sim/servicemodel.py": "SPEC_K = 4\n",
        "src/repro/serving/engine.py": "SPEC_K = 4\n",
    },
    "docs-anchors": {
        **MD_STUBS,
        "ROADMAP.md": "see §no-such-section\n",
    },
    "obs-lint": {
        **MD_STUBS,
        # a governed module reading a raw clock (and no longer resolving
        # the tracer) trips both wall-clock and emission
        "src/repro/core/network.py": """\
            import time

            def now():
                return time.perf_counter()
        """,
    },
}


class TestSeededFixtures:
    """Every rule fires on its seeded fixture — the same repos the CLI
    exit-code test uses."""

    def test_each_seeded_repo_trips_its_rule(self, tmp_path):
        for rule, files in SEEDED.items():
            root = mk_repo(tmp_path / rule.replace("/", "_"), files)
            report = analyze(root, rule)
            assert any(rule_matches(rule, r) for r in rule_ids(report)), \
                f"{rule} fixture produced {rule_ids(report)}"


class TestCompatBoundary:
    def test_import_attribute_and_kwarg_forms_fire(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/serving/x.py": """\
            import jax

            def f(m):
                jax.sharding.set_mesh(m)
                return jax.make_mesh((1,), ("d",), axis_types=(1,))
        """})
        ids = rule_ids(analyze(root, "compat-boundary"))
        assert ids.count("compat-boundary") == 2

    def test_compat_package_and_docstrings_are_silent(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/compat/x.py": """\
            from jax.sharding import use_mesh, set_mesh
        """, "src/repro/serving/y.py": '''\
            """Mentions use_mesh and AxisType only in prose."""
            # a comment about set_mesh is fine too
            X = 1
        '''})
        assert rule_ids(analyze(root, "compat-boundary")) == []


class TestLayering:
    def test_import_dag_violation_and_unknown_subpackage(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/core/x.py": "import repro.serving\n",
            "src/repro/mystery/y.py": "X = 1\n",
        })
        ids = rule_ids(analyze(root, "layering"))
        assert ids.count("layering/import-dag") == 2

    def test_sanctioned_edges_are_silent(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/serving/x.py": "from repro.sim import executor\n",
            "src/repro/core/y.py": "from repro.sim import workload\n",
        })
        assert rule_ids(analyze(root, "layering")) == []

    def test_executor_contract_missing_surface(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/sim/x.py": """\
            class Executor:
                pass

            class Partial(Executor):
                def admit(self, r):
                    return True

            class Full(Executor):
                def admit(self, r):
                    return True
                def load(self):
                    return None
                def estimate(self, r):
                    return 0.0
                @property
                def n_active(self):
                    return 0

            class Inheriting(Full):
                pass
        """})
        findings = analyze(root, "layering").new
        bad = [f for f in findings
               if f.rule_id == "layering/executor-contract"]
        assert len(bad) == 1 and "'Partial'" in bad[0].msg
        for m in ("load", "estimate", "n_active"):
            assert m in bad[0].msg

    def test_service_time_and_private_state_boundaries(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/core/x.py": """\
            def f(profile, eng):
                t = profile.service_time(10)
                return t + len(eng._free_pages)
        """})
        ids = rule_ids(analyze(root, "layering"))
        assert "layering/service-time" in ids
        assert "layering/private-state" in ids

    def test_digest_construction_confined_to_executor_layer(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            # hand-rolled digest outside the executor layer: flagged
            "src/repro/core/x.py": """\
            from repro.sim.executor import LoadDigest

            def fake(now):
                return LoadDigest(now, 1.0, 1.0, 0, 0, 1.0, 0)
        """,
            # the sanctioned projection home constructs freely
            "src/repro/sim/executor.py": """\
            class LoadDigest:
                pass

            def make_load_digest(load, now):
                return LoadDigest()
        """,
            # obtaining a digest via the projection helper is silent
            "src/repro/core/y.py": """\
            from repro.sim.executor import make_load_digest

            def ok(load, now):
                return make_load_digest(load, now)
        """})
        findings = analyze(root, "layering").new
        bad = [f for f in findings
               if f.rule_id == "layering/digest-construction"]
        assert len(bad) == 1
        assert bad[0].path == "src/repro/core/x.py"
        assert "make_load_digest" in bad[0].msg


class TestKernelLint:
    def test_nested_kernel_closure_capture(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/kernels/x.py": """\
            from jax.experimental import pallas as pl

            def run(x):
                scale = float(x.shape[0])

                def _k(x_ref, o_ref):
                    o_ref[...] = x_ref[...] * scale

                return pl.pallas_call(_k, grid=(1,))(x)
        """})
        findings = analyze(root, "kernel-lint").new
        closure = [f for f in findings if f.rule_id == "kernel-lint/closure"]
        assert len(closure) == 1 and "scale" in closure[0].msg

    def test_partial_bound_statics_are_silent(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/kernels/x.py": """\
            import functools
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, *, b):
                o_ref[...] = x_ref[...] * b

            def run(x, b):
                pad = (-x.shape[0]) % b
                kernel = functools.partial(_k, b=b)
                return pl.pallas_call(kernel, grid=(x.shape[0] // b,))(x)
        """})
        assert rule_ids(analyze(root, "kernel-lint")) == []

    def test_grid_division_without_evidence(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/kernels/x.py": """\
            import functools
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, *, b):
                o_ref[...] = x_ref[...]

            def run(x, b):
                kernel = functools.partial(_k, b=b)
                return pl.pallas_call(kernel, grid=(x.shape[0] // b,))(x)
        """})
        ids = rule_ids(analyze(root, "kernel-lint"))
        assert "kernel-lint/grid-divisibility" in ids

    def test_index_map_purity(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/kernels/x.py": """\
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref):
                o_ref[...] = x_ref[...]

            def helper(i):
                return i

            def run(x):
                return pl.pallas_call(
                    _k,
                    in_specs=[pl.BlockSpec((8,), lambda i: helper(i)),
                              pl.BlockSpec((8,), lambda i: pl.ds(i, 1))],
                    grid=(1,))(x)
        """})
        ids = rule_ids(analyze(root, "kernel-lint"))
        assert ids.count("kernel-lint/index-map") == 1

    def test_tunable_attribute_divisor_needs_evidence(self, tmp_path):
        # a grid axis divided by a tuning ATTRIBUTE (not a bare name) must
        # carry the same % evidence; the bare-name check alone misses it
        bad = {**MD_STUBS, "src/repro/kernels/x.py": """\
            import functools
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, *, b):
                o_ref[...] = x_ref[...]

            def run(x, tuning):
                kernel = functools.partial(_k, b=tuning.pages_per_step)
                return pl.pallas_call(
                    kernel, grid=(x.shape[0] // tuning.pages_per_step,))(x)
        """}
        ids = rule_ids(analyze(mk_repo(tmp_path / "bad", bad), "kernel-lint"))
        assert "kernel-lint/grid-divisibility" in ids
        good = {**MD_STUBS, "src/repro/kernels/x.py": """\
            import functools
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, *, b):
                o_ref[...] = x_ref[...]

            def run(x, tuning):
                pad = (-x.shape[0]) % tuning.pages_per_step
                kernel = functools.partial(_k, b=tuning.pages_per_step)
                return pl.pallas_call(
                    kernel,
                    grid=((x.shape[0] + pad) // tuning.pages_per_step,))(x)
        """}
        assert rule_ids(analyze(mk_repo(tmp_path / "good", good),
                                "kernel-lint")) == []

    def test_dequant_helper_redefined_in_pallas_module(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/kernels/x.py": """\
            from jax.experimental import pallas as pl

            def kv_dequantize(q, scale, dtype):
                return q.astype(dtype) * scale

            def _k(x_ref, s_ref, o_ref):
                o_ref[...] = kv_dequantize(x_ref[...], s_ref[...], float)

            def run(x, s):
                return pl.pallas_call(_k, grid=(1,))(x, s)
        """})
        ids = rule_ids(analyze(root, "kernel-lint"))
        # both the local re-definition and the call resolving to it fire
        assert ids.count("kernel-lint/dequant-import") == 2

    def test_dequant_imported_from_attention_is_silent(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/kernels/x.py": """\
            from jax.experimental import pallas as pl
            from repro.models.attention import kv_dequantize

            def _k(x_ref, s_ref, o_ref):
                o_ref[...] = kv_dequantize(x_ref[...], s_ref[...], float)

            def run(x, s):
                return pl.pallas_call(_k, grid=(1,))(x, s)
        """})
        assert rule_ids(analyze(root, "kernel-lint")) == []


class TestTwinDrift:
    def test_redefining_shared_constant_and_predicate(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/sim/servicemodel.py": "SPEC_K = 4\nKV = {}\n",
            "src/repro/serving/engine.py": """\
                SPEC_K = 4

                def paged_admit_ok(load, req):
                    return True
            """,
        })
        ids = rule_ids(analyze(root, "twin-drift"))
        assert ids.count("twin-drift/shared-name") == 2

    def test_importing_shared_names_is_silent(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/sim/servicemodel.py": "SPEC_K = 4\n",
            "src/repro/serving/engine.py":
                "from repro.sim.servicemodel import SPEC_K\n"
                "LOCAL_ONLY = 3\n",
        })
        assert rule_ids(analyze(root, "twin-drift")) == []

    def test_redefining_prefix_predicates_flagged(self, tmp_path):
        """The §6.1-prefix hit rule is a registered shared predicate: a
        local re-implementation in an engine or benchmark module is drift,
        both as a function def and as a shadowing assignment."""
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/serving/engine.py": """\
                def prefix_hit_pages(prompt, page, matched):
                    return matched // page
            """,
            "benchmarks/run.py": "prefix_fingerprint_id = hash\n",
        })
        ids = rule_ids(analyze(root, "twin-drift"))
        assert ids.count("twin-drift/shared-name") == 2

    def test_importing_prefix_predicates_is_silent(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/serving/engine.py":
                "from repro.sim.executor import prefix_hit_pages\n",
            "src/repro/core/network.py":
                "from repro.sim.executor import prefix_fingerprint_id\n",
        })
        assert rule_ids(analyze(root, "twin-drift")) == []

    def test_duplicate_constant_across_modules(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/kernels/a.py": "NEG_INF = -1e30\n",
            "src/repro/kernels/b.py": "NEG_INF = -1e30\n",
            "src/repro/models/c.py": "_PRIVATE = 1.0\n",
            "src/repro/models/d.py": "_PRIVATE = 1.0\n",
        })
        ids = rule_ids(analyze(root, "twin-drift"))
        # both public copies flagged; private (_-prefixed) ones exempt
        assert ids.count("twin-drift/duplicate-const") == 2


class TestObsLint:
    def test_span_ctor_outside_obs_fires(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/core/x.py": """\
            from repro.obs.tracer import Span

            def f(spans):
                spans.append(Span("route.decide", "r1", "n0", 0.0, 1.0))
        """})
        findings = analyze(root, "obs-lint").new
        bad = [f for f in findings
               if f.rule_id == "obs-lint/span-construction"]
        assert len(bad) == 1 and bad[0].path == "src/repro/core/x.py"

    def test_obs_home_and_tracer_api_are_silent(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            # the sanctioned home constructs Span freely
            "src/repro/obs/tracer.py": """\
            class Span:
                pass

            def span(name):
                return Span()
        """,
            # recording through the Tracer API is the idiomatic form
            "src/repro/core/x.py": """\
            from repro.obs import get_tracer

            def f(rid):
                tr = get_tracer()
                if tr.enabled:
                    tr.span("route.decide", rid, "n0", 0.0, 1.0)
        """})
        assert rule_ids(analyze(root, "obs-lint")) == []

    def test_raw_clock_in_governed_module_fires(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            "src/repro/serving/engine.py": """\
            import time
            from time import perf_counter

            from repro.obs import get_tracer

            def step():
                get_tracer()
                return perf_counter() - time.time() + time.monotonic()
        """})
        ids = rule_ids(analyze(root, "obs-lint"))
        # perf_counter(), time.time(), time.monotonic() — three reads
        assert ids.count("obs-lint/wall-clock") == 3
        assert "obs-lint/emission" not in ids

    def test_wall_now_and_ungoverned_clocks_are_silent(self, tmp_path):
        root = mk_repo(tmp_path, {
            **MD_STUBS,
            # governed module stamping through the sanctioned API
            "src/repro/serving/engine.py": """\
            from repro.obs import get_tracer, wall_now

            def step(self):
                with get_tracer().wall("engine.decode_step") as sp:
                    t = wall_now()
                return t
        """,
            # raw clocks outside the governed set (drivers, benches) are
            # not obs-lint's business
            "src/repro/launch/serve.py": """\
            import time

            def main():
                return time.perf_counter()
        """})
        assert rule_ids(analyze(root, "obs-lint")) == []

    def test_governed_module_without_tracer_fires_emission(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/core/node.py": """\
            def enqueue(qr):
                return qr
        """})
        ids = rule_ids(analyze(root, "obs-lint"))
        assert ids == ["obs-lint/emission"]


class TestDocAnchors:
    def test_missing_required_heading(self, tmp_path):
        files = dict(MD_STUBS)
        files["DESIGN.md"] = DESIGN_OK.replace("## §7 Analysis\n", "")
        root = mk_repo(tmp_path, files)
        findings = analyze(root, "docs-anchors").new
        assert any(f.rule_id == "docs-anchors/required" and "§7" in f.msg
                   for f in findings)

    def test_python_attribution_window(self, tmp_path):
        # the anchor sign is spelled as an escape so THIS file's source
        # carries no attributed dangling anchors for the live pass to see
        sec = "§"
        body = (f'"""Paged admission (DESIGN.md\n'
                f'{sec}6.1-paged) vs dangling (DESIGN.md {sec}9.9); the '
                f'paper\'s {sec}5 and\n'
                f'EXPERIMENTS.md {sec}Roofline have no attribution."""\n'
                f'X = 1\n')
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/sim/x.py": body})
        findings = analyze(root, "docs-anchors").new
        # §6.1-paged resolves (wrapped attribution); §9.9 dangles; §5 and
        # §Roofline sit after another anchor / other files — unattributed
        assert [f.rule_id for f in findings] == ["docs-anchors/python"]
        assert f"{sec}9.9" in findings[0].msg


class TestSuppression:
    def test_inline_and_comment_above_suppress(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/serving/x.py": """\
            from jax.sharding import use_mesh  # repro: allow[compat-boundary]

            # justified exception:  # repro: allow[compat-boundary]
            from jax.sharding import set_mesh
        """})
        report = analyze(root, "compat-boundary")
        assert report.new == []
        assert len(report.suppressed) == 2

    def test_suppression_is_rule_scoped(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS, "src/repro/serving/x.py": """\
            from jax.sharding import use_mesh  # repro: allow[layering]
        """})
        report = analyze(root, "compat-boundary")
        assert rule_ids(report) == ["compat-boundary"]


class TestBaseline:
    def test_round_trip_grandfathers_then_empties(self, tmp_path):
        root = mk_repo(tmp_path, SEEDED["compat-boundary"])
        strict = analyze(root, "compat-boundary")
        assert strict.new

        bl = root / BASELINE_FILE
        save_baseline(bl, strict.new)
        assert [tuple(k) for k in load_baseline(bl)] == \
            [f.key() for f in sorted(strict.new)]

        # default pickup: run_analysis finds <root>/analysis_baseline.json
        graced = run_analysis(root, rules=["compat-boundary"])
        assert graced.new == []
        assert [f.key() for f in graced.baselined] == \
            [f.key() for f in sorted(strict.new)]

        # a NEW violation still fails even with the baseline in place
        (root / "src/repro/serving/y.py").write_text(
            "from jax.sharding import set_mesh\n")
        report = run_analysis(root, rules=["compat-boundary"])
        assert len(report.new) == 1
        assert report.new[0].path == "src/repro/serving/y.py"

    def test_parse_error_becomes_finding(self, tmp_path):
        root = mk_repo(tmp_path, {**MD_STUBS,
                                  "src/repro/sim/x.py": "def broken(:\n"})
        report = analyze(root)
        assert any(f.rule_id == "parse-error" for f in report.new)


class TestCLI:
    """python -m repro.analysis: exit codes and --json over seeded repos."""

    def _run(self, *args, cwd):
        env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True, cwd=cwd, env=env, timeout=60)

    def test_exits_nonzero_on_each_seeded_violation(self, tmp_path):
        for rule, files in SEEDED.items():
            root = mk_repo(tmp_path / rule.replace("/", "_"), files)
            res = self._run("--root", str(root), "--json", cwd=REPO)
            assert res.returncode == 1, f"{rule}: {res.stdout}\n{res.stderr}"
            payload = json.loads(res.stdout)
            assert payload["counts"]["new"] >= 1
            assert any(rule_matches(rule, f["rule_id"])
                       for f in payload["new"]), rule

    def test_exits_zero_on_this_repo(self):
        res = self._run("--root", str(REPO), cwd=REPO)
        assert res.returncode == 0, res.stdout + res.stderr

    def test_list_rules_names_all_six(self):
        res = self._run("--list-rules", cwd=REPO)
        assert res.returncode == 0
        for rule in ALL_RULES:
            assert rule in res.stdout


class TestLivePass:
    """Tier-1 acceptance: the analyzer over THIS repository."""

    def test_all_six_checkers_registered(self):
        assert [c.rule_id for c in all_checkers()] == sorted(ALL_RULES)

    def test_repo_is_clean_and_fast(self):
        report = run_analysis(REPO)
        assert sorted(report.rules) == sorted(ALL_RULES)
        assert report.new == [], "new findings:\n  " + "\n  ".join(
            f.format() for f in report.new)
        assert report.wall_s < 10.0, f"analysis took {report.wall_s:.1f}s"

    def test_committed_baseline_is_empty(self):
        # the goal state (DESIGN.md §7): fix or justify inline, never
        # accumulate grandfathered debt
        assert load_baseline(REPO / BASELINE_FILE) == []

    def test_repo_index_sees_all_scan_dirs(self):
        repo = RepoIndex(REPO)
        files = repo.py_files()
        assert any(f.startswith("src/repro/") for f in files)
        assert any(f.startswith("tests/") for f in files)
        assert any(f.startswith("benchmarks/") for f in files)
        assert repo.module_name("src/repro/sim/executor.py") == \
            "repro.sim.executor"

    def test_finding_format_and_ordering(self):
        a = Finding("r", "a.py", 3, "m")
        b = Finding("r", "a.py", 9, "m")
        assert a.format() == "a.py:3: [r] m"
        assert sorted([b, a]) == [a, b]
        assert a.key() == ("r", "a.py", "m")
