"""Mixture-of-Experts transformer (granite-3.0-MoE, DBRX).

Attention is shared with the dense family; the MLP is replaced by a top-k
token-choice router with capacity-based, sort-free dispatch:

* per batch row, tokens are argsorted by assigned expert; the rank of a token
  within its expert comes from a searchsorted difference (no (T,E) one-hot);
* tokens beyond the per-expert capacity C = ceil(S·k/E · cf) are dropped
  (standard Switch/GShard semantics);
* dispatch/combine are gather / scatter-add with a sentinel index (out-of-
  range writes are dropped by XLA), so the only materialized buffer is
  (B, E, C, d) — sharded over ``model`` on the expert axis.

Expert compute is a single batched einsum over the expert axis, which the
mesh shards over ``model`` (expert parallelism).  The combine induces one
all-reduce over ``model`` per MoE layer — the baseline recorded in the
roofline; an explicit all-to-all shard_map variant is a §Perf iteration.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import meshenv
from repro.models import common as cm
from repro.models import runtime
from repro.models import dense
from repro.models.attention import flash_attention
from repro.models.config import ModelConfig


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def expert_capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    c = int(tokens_per_row * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cfg.top_k, min(c, tokens_per_row))


# --------------------------------------------------------------------- init
def init(key: jax.Array, cfg: ModelConfig) -> Dict:
    p = dense.init(key, cfg)
    lyr = p["layers"]
    # replace dense MLP weights by router + per-expert SwiGLU weights
    for name in ("w_gate", "w_up", "w_down", "b_up", "b_down"):
        lyr.pop(name, None)
    L, d, f, E = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 4)

    def stack_expert(k, d_in, d_out):
        ks = jax.random.split(k, L * E)
        w = [cm.dense_init(ks[i], d_in, d_out, _dt(cfg)) for i in range(L * E)]
        return jnp.stack(w).reshape(L, E, d_in, d_out)

    lyr["router"] = jnp.stack([
        cm.dense_init(kk, d, E, jnp.float32, scale=0.1)
        for kk in jax.random.split(keys[0], L)])
    lyr["we_gate"] = stack_expert(keys[1], d, f)
    lyr["we_up"] = stack_expert(keys[2], d, f)
    lyr["we_down"] = stack_expert(keys[3], f, d)
    return p


# ---------------------------------------------------------------- MoE layer
def route(cfg: ModelConfig, router_w: jax.Array, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,d) -> (gates (B,S,k), experts (B,S,k), aux_loss ())."""
    logits = x.astype(jnp.float32) @ router_w            # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss: E * Σ_e f_e · P_e
    me = jnp.mean(probs, axis=(0, 1))                                  # (E,)
    one_hot_top1 = jax.nn.one_hot(experts[..., 0], cfg.n_experts)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(me * ce)
    return gates, experts, aux


def _dispatch(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """Sort-free capacity dispatch.  x: (B,S,d) -> (xin (B,E,C,d),
    disp (B,E*C) token idx, gsel (B,E*C) gates, aux loss)."""
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = expert_capacity(cfg, s)
    gates, experts, aux = route(cfg, router_w, x)

    # flatten the k assignments: (B, S*k)
    ef = experts.reshape(b, s * k)
    gf = gates.reshape(b, s * k)
    order = jnp.argsort(ef, axis=1, stable=True)                 # (B, S*k)
    e_sorted = jnp.take_along_axis(ef, order, axis=1)
    g_sorted = jnp.take_along_axis(gf, order, axis=1)
    tok_sorted = order // k                                      # token index
    # rank of each entry within its expert
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(e_sorted)
    rank = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, e_sorted,
                                                            axis=1)
    keep = rank < C
    slot = e_sorted * C + jnp.minimum(rank, C - 1)               # (B, S*k)
    slot = jnp.where(keep, slot, E * C)                          # sentinel

    # dispatch: token index per (expert, capacity) slot; sentinel = S (pad row)
    disp = jnp.full((b, E * C + 1), s, jnp.int32)
    disp = disp.at[jnp.arange(b)[:, None], slot].set(tok_sorted, mode="drop")
    disp = disp[:, : E * C]
    gsel = jnp.zeros((b, E * C + 1), jnp.float32)
    gsel = gsel.at[jnp.arange(b)[:, None], slot].set(g_sorted, mode="drop")
    gsel = gsel[:, : E * C]

    xp = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xin = jnp.take_along_axis(xp, disp[:, :, None], axis=1)      # (B, E*C, d)
    return xin.reshape(b, E, C, d), disp, gsel, aux


def _combine(x: jax.Array, eout: jax.Array, disp: jax.Array,
             gsel: jax.Array) -> jax.Array:
    """Scatter-add expert outputs back to token order. eout: (B,E,C,d)."""
    b, s, d = x.shape
    ec = disp.shape[1]
    eout = eout.reshape(b, ec, d).astype(jnp.float32) * gsel[:, :, None]
    out = jnp.zeros((b, s, d), jnp.float32)
    out = out.at[jnp.arange(b)[:, None], disp].add(eout, mode="drop")
    return out.astype(x.dtype)


def moe_mlp(cfg: ModelConfig, lp: Dict, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based top-k expert MLP. x: (B,S,d) -> (B,S,d), aux loss."""
    if runtime.moe_a2a():
        out = _moe_mlp_a2a(cfg, lp, x)
        if out is not None:
            return out
    xin, disp, gsel, aux = _dispatch(cfg, lp["router"], x)
    xin = cm.shard(xin, "batch", "model", None, None)

    h = jnp.einsum("becd,edf->becf", xin, lp["we_gate"])
    u = jnp.einsum("becd,edf->becf", xin, lp["we_up"])
    h = cm.shard(jax.nn.silu(h) * u, "batch", "model", None, None)
    eout = jnp.einsum("becf,efd->becd", h, lp["we_down"])        # (B,E,C,d)
    out = _combine(x, eout, disp, gsel)
    return cm.shard(out, "batch", "seq", None), aux


def _moe_mlp_a2a(cfg: ModelConfig, lp: Dict, x: jax.Array):
    """§Perf variant: explicit expert-parallel all-to-all dispatch.

    The baseline keeps activations replicated over 'model' and lets the
    combine scatter-add psum into an all-reduce of the full (B,S,d) stream.
    Here the layer runs in shard_map: tokens sequence-sharded over 'model',
    each shard routes ONLY its tokens, and two lax.all_to_all calls move just
    the (E, C, d) expert buffers (≈ top_k/E of the activation bytes) to and
    from the expert-owning shards.  Returns None if shapes don't divide
    (falls back to the einsum path).
    """
    from jax.sharding import PartitionSpec as P
    mesh = meshenv.current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return None
    m = meshenv.mesh_size(mesh, "model")
    b, s, d = x.shape
    bx = tuple(a for a in cm.BATCH_AXES if a in mesh.axis_names)
    nb = meshenv.mesh_size(mesh, bx)
    if m == 1 or cfg.n_experts % m or s % m or (bx and b % nb):
        return None
    b_spec = bx if bx else None
    e_loc = cfg.n_experts // m

    def local(x_l, router_w, wg, wu, wd):
        # x_l: (B_l, S/m, d); wg/wu/wd: (E_loc, ...) — this shard's experts
        xin, disp, gsel, aux = _dispatch(cfg, router_w, x_l)   # (B_l,E,C,d)
        # send each expert's buffer to its owning shard
        recv = jax.lax.all_to_all(xin, "model", split_axis=1, concat_axis=2,
                                  tiled=True)                  # (B_l,e_loc,m*C,d)
        h = jnp.einsum("becd,edf->becf", recv, wg)
        u = jnp.einsum("becd,edf->becf", recv, wu)
        eout = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, wd)
        back = jax.lax.all_to_all(eout, "model", split_axis=2, concat_axis=1,
                                  tiled=True)                  # (B_l,E,C,d)
        out = _combine(x_l, back, disp, gsel)
        return out, jax.lax.pmean(aux, "model")

    fn = meshenv.shard_map(local, mesh=mesh,
                           in_specs=(P(b_spec, "model", None), P(),
                                     P("model", None, None),
                                     P("model", None, None),
                                     P("model", None, None)),
                           out_specs=(P(b_spec, "model", None), P()),
                           check_rep=False)
    out, aux = fn(x, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])
    return cm.shard(out, "batch", "seq", None), aux


# ------------------------------------------------------------------- forward
def _block(lp: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
           q_chunk: int, kv_chunk: int) -> Tuple[jax.Array, jax.Array]:
    h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
    q, k, v = dense._project_qkv(lp, cfg, h, positions)
    attn = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)
    attn = attn.reshape(x.shape[0], x.shape[1], cfg.q_dim) @ lp["wo"]
    x = x + attn
    h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
    mlp_out, aux = moe_mlp(cfg, lp, h2)
    return cm.shard(x + mlp_out, "batch", "seq", None), aux


def apply(params: Dict, cfg: ModelConfig, batch: Dict, *,
          q_chunk: int = 1024, kv_chunk: int = 1024
          ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits, aux_loss)."""
    x, positions = dense.embed_inputs(params, cfg, batch)
    s = x.shape[1]
    fn = functools.partial(_block, cfg=cfg, positions=positions,
                           q_chunk=min(q_chunk, s), kv_chunk=min(kv_chunk, s))
    body = jax.checkpoint(lambda carry, lp: fn(lp, x=carry))
    x, auxes = jax.lax.scan(body, x, params["layers"],
                            unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    return dense.logits_of(params, cfg, x), jnp.mean(auxes)


# --------------------------------------------------------------- decode path
def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, token: jax.Array
                ) -> Tuple[jax.Array, Dict]:
    x = jnp.take(params["embed"], token, axis=0)
    length = cache["length"]

    def step(x, xs):
        lp, kc, vc = xs
        b = x.shape[0]
        cap = kc.shape[1]
        h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
        pos = jnp.broadcast_to(length.reshape(1, 1), (b, 1))
        q, k, v = dense._project_qkv(lp, cfg, h, pos)
        slot = jnp.mod(length, cap)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        from repro.models.attention import decode_attention
        attn = decode_attention(q, kc, vc, jnp.minimum(length + 1, cap))
        attn = attn.reshape(b, 1, cfg.q_dim) @ lp["wo"]
        x = x + attn
        h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
        mlp_out, _ = moe_mlp(cfg, lp, h2)
        return x + mlp_out, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        step, x, (params["layers"], cache["k"], cache["v"]),
        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    return dense.logits_of(params, cfg, x), {"k": k_new, "v": v_new,
                                             "length": length + 1}


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            q_chunk: int = 1024, kv_chunk: int = 1024,
            capacity: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    x, positions = dense.embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    if cfg.sliding_window is None:
        cap = max(s, capacity or s)
    else:
        cap = min(cfg.sliding_window, capacity or cfg.sliding_window)

    def step(carry, lp):
        x = carry
        h = cm.apply_norm(x, lp["ln1"], cfg.norm_type)
        q, k, v = dense._project_qkv(lp, cfg, h, positions)
        attn = flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                               q_chunk=min(q_chunk, s), kv_chunk=min(kv_chunk, s))
        attn = attn.reshape(b, s, cfg.q_dim) @ lp["wo"]
        x = x + attn
        h2 = cm.apply_norm(x, lp["ln2"], cfg.norm_type)
        mlp_out, _ = moe_mlp(cfg, lp, h2)
        x = cm.shard(x + mlp_out, "batch", "seq", None)
        if cap <= s:
            kk = jnp.roll(k[:, -cap:], shift=s % cap, axis=1)
            vv = jnp.roll(v[:, -cap:], shift=s % cap, axis=1)
        else:
            padw = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
            kk, vv = jnp.pad(k, padw), jnp.pad(v, padw)
        return x, (kk, vv)

    step = jax.checkpoint(step)
    x, (ks, vs) = jax.lax.scan(step, x, params["layers"],
                               unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = dense.logits_of(params, cfg, x[:, -1:])
    return logits, {"k": ks, "v": vs, "length": jnp.asarray(s, jnp.int32)}


init_cache = dense.init_cache
