"""Experimental settings (paper Appendix C, Table 3), shared by benchmarks.

Each node: (model, gpu, backend, [(interval, 1/lambda), ...]).  The paper's
inter-arrival times are scaled by ARRIVAL_SCALE to hit comparable saturation
under our calibrated service model; every node uses the paper's policy
defaults (offload 80%, accept 80%, target util 70%, max tokens 8192).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import WorkloadSpec, make_profile
from repro.sim.servicemodel import MODEL_QUALITY
from repro.sim.workload import ArrivalPhase

T_END = 750.0
OUTPUT_MEAN = 5120          # OpenR1-Math reasoning traces are long
SLO_S = 360.0
OFFLOAD_UTIL = 0.8          # offload once utilization passes 80% of the knee

# (model, gpu, backend, [(t0, t1, inter-arrival s), ...])
NodeSpec = Tuple[str, str, str, List[Tuple[float, float, float]]]

SETTINGS: Dict[str, List[NodeSpec]] = {
    "setting1": [
        ("qwen3-8b", "ADA6000", "sglang", [(0, 300, 5), (300, 750, 20)]),
        ("qwen3-8b", "ADA6000", "sglang", [(0, 750, 20)]),
        ("qwen3-8b", "ADA6000", "sglang", [(0, 750, 20)]),
        ("qwen3-8b", "ADA6000", "sglang", [(0, 450, 20), (450, 750, 5)]),
    ],
    "setting2": [
        ("qwen3-8b", "ADA6000", "sglang", [(0, 300, 4), (300, 750, 20)]),
        ("qwen3-8b", "ADA6000", "sglang", [(0, 750, 20)]),
        ("qwen3-4b", "RTX3090", "sglang", [(0, 750, 30)]),
        ("qwen3-4b", "RTX3090", "sglang", [(0, 450, 30), (450, 750, 6)]),
    ],
    "setting3": [
        ("qwen3-32b", "4xA100", "sglang", [(0, 300, 2), (300, 750, 6)]),
        ("qwen3-8b", "L40S", "sglang", [(0, 750, 15)]),
        ("deepseek-qwen-7b", "RTX3090", "vllm", [(0, 750, 30)]),
        ("llama3.1-8b", "ADA6000", "vllm", [(0, 450, 15), (450, 750, 5)]),
    ],
    "setting4": [
        ("llama3.1-8b", "L40S", "vllm", [(0, 750, 9)]),
        ("llama3.1-8b", "L40S", "vllm", [(0, 450, 6), (450, 750, 12)]),
        ("deepseek-qwen-7b", "ADA6000", "vllm", [(0, 300, 6), (300, 750, 12)]),
        ("deepseek-qwen-7b", "ADA6000", "vllm", [(0, 450, 12), (450, 750, 6)]),
        ("qwen3-4b", "RTX4090", "sglang", [(0, 750, 12)]),
        ("qwen3-4b", "RTX4090", "sglang", [(0, 450, 10), (450, 750, 20)]),
        ("qwen3-4b", "RTX3090", "sglang", [(0, 300, 20), (300, 750, 10)]),
        ("qwen3-4b", "RTX3090", "sglang", [(0, 300, 20), (300, 750, 10)]),
    ],
}

# the paper's absolute 1/λ values assume its hardware pool; we scale them so
# the calibrated service model reaches the same saturation regimes
ARRIVAL_SCALE = {"setting1": 0.6, "setting2": 0.6, "setting3": 0.6,
                 "setting4": 0.6}


def build_network(setting: str, mode: str, seed: int = 0,
                  duel: DuelParams | None = None,
                  policy_overrides: Dict[int, NodePolicy] | None = None
                  ) -> Tuple[Network, List[WorkloadSpec]]:
    nodes = SETTINGS[setting]
    net = Network(mode=mode, seed=seed, ledger_mode="shared",
                  duel=duel or DuelParams(p_d=0.1, k_judges=2),
                  init_balance=100.0)
    specs: List[WorkloadSpec] = []
    scale = ARRIVAL_SCALE.get(setting, 1.0)
    for i, (model, gpu, backend, phases) in enumerate(nodes):
        nid = f"node{i + 1}"
        prof = make_profile(model, gpu, backend,
                            quality=MODEL_QUALITY.get(model, 0.5))
        pol = (policy_overrides or {}).get(
            i, NodePolicy(offload_util_threshold=OFFLOAD_UTIL))
        net.add_node(Node(nid, prof, policy=pol))
        specs.append(WorkloadSpec(
            nid, [ArrivalPhase(t0, t1, ia * scale) for t0, t1, ia in phases],
            output_mean=OUTPUT_MEAN, slo_s=SLO_S))
    return net, specs
