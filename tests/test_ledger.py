"""Credit block chain: hash links, signatures, double-spend, conservation."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.ledger import (BalanceView, CreditBlock, CreditChain,
                               CreditOp, LedgerError, SharedLedger, sign)


def _chain_with_funds(owner="a", amount=100.0):
    c = CreditChain(owner)
    c.append(c.propose([CreditOp("mint", "", owner, amount)], 0.0, b"s"))
    return c


class TestChain:
    def test_append_and_balances(self):
        c = _chain_with_funds()
        c.append(c.propose([CreditOp("stake", "a", "", 30.0)], 1.0, b"s"))
        assert c.balance_of("a") == pytest.approx(70.0)
        assert c.stake_of("a") == pytest.approx(30.0)
        assert c.verify_chain()

    def test_double_spend_rejected(self):
        c = _chain_with_funds(amount=10.0)
        ok, why = c.validate(c.propose(
            [CreditOp("transfer", "a", "b", 8.0),
             CreditOp("transfer", "a", "b", 8.0)], 1.0, b"s"))
        assert not ok and "double-spend" in why

    def test_tamper_detection(self):
        c = _chain_with_funds()
        blk = c.propose([CreditOp("transfer", "a", "b", 5.0)], 1.0, b"s")
        bad = dataclasses.replace(
            blk, operations=(CreditOp("transfer", "a", "b", 50.0),))
        ok, why = c.validate(bad)
        assert not ok and "tamper" in why

    def test_wrong_parent_rejected(self):
        c = _chain_with_funds()
        blk = c.propose([CreditOp("transfer", "a", "b", 5.0)], 1.0, b"s")
        c.append(blk)
        ok, why = c.validate(blk)          # replay: parent no longer head
        assert not ok

    def test_signature_verification(self):
        c = _chain_with_funds()
        blk = c.propose([CreditOp("transfer", "a", "b", 1.0)], 1.0, b"secret")
        assert c.validate(blk, proposer_secret=b"secret")[0]
        assert not c.validate(blk, proposer_secret=b"other")[0]

    def test_full_chain_audit_catches_mutation(self):
        c = _chain_with_funds()
        for i in range(5):
            c.append(c.propose([CreditOp("transfer", "a", f"b{i}", 1.0)],
                               float(i), b"s"))
        assert c.verify_chain()
        c.blocks[2] = dataclasses.replace(
            c.blocks[2], operations=(CreditOp("mint", "", "evil", 1e6),))
        assert not c.verify_chain()

    def test_slash_cannot_exceed_stake(self):
        c = _chain_with_funds()
        c.append(c.propose([CreditOp("stake", "a", "", 5.0)], 1.0, b"s"))
        ok, _ = c.validate(c.propose([CreditOp("slash", "a", "", 9.0)],
                                     2.0, b"s"))
        assert not ok


@st.composite
def op_sequences(draw):
    nodes = ["a", "b", "c"]
    ops = [CreditOp("mint", "", n, 100.0) for n in nodes]
    for _ in range(draw(st.integers(0, 30))):
        kind = draw(st.sampled_from(["transfer", "stake", "unstake", "slash",
                                     "reward"]))
        src = draw(st.sampled_from(nodes))
        dst = draw(st.sampled_from(nodes))
        amt = draw(st.floats(0.0, 20.0, allow_nan=False))
        ops.append(CreditOp(kind, src, dst, amt))
    return ops


class TestConservation:
    @given(op_sequences())
    @settings(max_examples=60, deadline=None)
    def test_total_credit_conserved_minus_slashes(self, ops):
        """Invariant: total(balance+stake) == mints - slashes applied."""
        v = BalanceView()
        minted = slashed = 0.0
        for op in ops:
            try:
                before = v.total()
                v.apply(op)
            except LedgerError:
                continue
            if op.kind == "mint":
                minted += op.amount
            elif op.kind == "slash":
                slashed += op.amount
        assert v.total() == pytest.approx(minted - slashed, abs=1e-6)
        assert all(b > -1e-9 for b in v.balance.values())
        assert all(s > -1e-9 for s in v.stake.values())

    @given(op_sequences())
    @settings(max_examples=30, deadline=None)
    def test_chain_replay_equals_view(self, ops):
        """Appending op-by-op == full replay from genesis."""
        c = CreditChain("prop")
        for i, op in enumerate(ops):
            blk = c.propose([op], float(i), b"s")
            ok, _ = c.validate(blk)
            if ok:
                c.append(blk)
        assert c.verify_chain()


class TestSharedLedger:
    def test_atomic_application(self):
        sl = SharedLedger()
        sl.apply([CreditOp("mint", "", "a", 10.0)])
        with pytest.raises(LedgerError):
            sl.apply([CreditOp("transfer", "a", "b", 6.0),
                      CreditOp("transfer", "a", "b", 6.0)])
        # first op must NOT have been applied
        assert sl.balance_of("a") == pytest.approx(10.0)
        assert sl.balance_of("b") == pytest.approx(0.0)
