"""Collection guard: fail fast, with an actionable message, before pytest
prints 10 modules' worth of identical ImportError tracebacks.

The root ``conftest.py`` bootstraps ``sys.path`` and the hypothesis shim;
this file verifies the environment actually works (repro importable, jax
present, property-test API available) and aborts collection with one clear
diagnostic when it doesn't.

It also turns on strict JAX numerics for the whole suite: implicit rank
promotion (``(4,) + (2, 4)``-style broadcasts) is the classic source of
silently wrong attention masks, so tier-1 runs with
``jax_numpy_rank_promotion="raise"`` — shape intent must be written out.
"""

import pytest


def _guard() -> None:
    problems = []
    try:
        import repro  # noqa: F401
    except ImportError as e:
        problems.append(
            f"cannot import 'repro' ({e}); run tests from the repo root "
            f"(root conftest.py adds src/ to sys.path) or set "
            f"PYTHONPATH=src")
    try:
        import jax  # noqa: F401
    except ImportError as e:
        problems.append(f"jax is required for the test suite: {e}")
    try:
        from hypothesis import given, settings, strategies  # noqa: F401
    except ImportError as e:
        problems.append(
            f"hypothesis API unavailable ({e}); the root conftest.py "
            f"should have installed repro.compat.hypothesis_shim")
    if problems:
        raise pytest.UsageError(
            "test environment broken:\n  - " + "\n  - ".join(problems))


def _strict_jax() -> None:
    import jax
    jax.config.update("jax_numpy_rank_promotion", "raise")


_guard()
_strict_jax()
