"""xLSTM-1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks (7:1 pattern)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,                 # 6 x (7 mLSTM + 1 sLSTM)
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                      # blocks carry their own projections
    vocab_size=50304,
    head_dim=512,
    xlstm_pattern=("m",) * 7 + ("s",),
    xlstm_up_factor=2.0,
    conv_width=4,
)
