"""EngineExecutor: the real-engine backend behind the Executor contract.

Wraps the slot-based continuous-batching ``Engine`` (DESIGN.md §6.1) so the
end-to-end driver in ``repro.launch.serve`` can treat real JAX inference
and the simulated ``TokenBucketExecutor`` uniformly: KV-budget-aware
``admit``, step-driven progress, a ``load()`` snapshot (active slots /
queued tokens / KV headroom), and a completion callback carrying
wall-clock start and first-token times.

Unlike the simulated backend there is no ambient event loop: the engine
runs in wall-clock time, so callers pump ``step()`` (one engine iteration:
sample, retire, admit, decode) or ``drain()`` themselves.
"""

from __future__ import annotations

from typing import List, Optional

from repro.serving.engine import Engine, GenRequest
from repro.sim.executor import Executor, ExecutorLoad, paged_admit_ok


class EngineExecutor(Executor):
    def __init__(self, engine: Engine,
                 max_pending_tokens: Optional[int] = None,
                 gate_on_pages: bool = False) -> None:
        self.engine = engine
        # admission bound: queued-but-unstarted work the executor will hold
        # before pushing back on the caller (None = unbounded)
        self.max_pending_tokens = max_pending_tokens
        # paged engines only: push back at admit() time with the same
        # page-granularity rule the engine applies at prefill time
        # (repro.sim.executor.paged_admit_ok), so a caller that respects
        # admit() sees the identical notion of "full" as the simulated
        # TokenBucketExecutor in page mode
        self.gate_on_pages = gate_on_pages
        self._loop = None
        self._on_complete = None

    # ------------------------------------------------------------- interface
    @property
    def n_active(self) -> int:
        return self.engine.active_slots()

    def admit(self, item: GenRequest) -> bool:
        if self.gate_on_pages or self.max_pending_tokens is not None:
            snap = self.engine.load_snapshot()
            if self.gate_on_pages and self.engine.paged:
                resident = snap["active_streams"] + snap["queued_streams"] > 0
                if not paged_admit_ok(snap["free_pages"], len(item.tokens),
                                      snap["page_size"], resident=resident):
                    return False
            if self.max_pending_tokens is not None:
                pending = (snap["queued_prompt_tokens"]
                           + snap["queued_new_tokens"])
                if (snap["queued_streams"] > 0
                        and pending + len(item.tokens) + item.max_new
                        > self.max_pending_tokens):
                    return False
        self.engine.submit(item)
        return True

    def load(self) -> ExecutorLoad:
        snap = self.engine.load_snapshot()
        return ExecutorLoad(
            active_streams=snap["active_streams"],
            queued_streams=snap["queued_streams"],
            pending_prefill_tokens=snap["queued_prompt_tokens"],
            pending_decode_tokens=(snap["pending_decode_tokens"]
                                   + snap["queued_new_tokens"]),
            kv_used=snap["kv_used"],
            kv_budget=snap["kv_budget"],
            pages_used=snap["pages_used"],
            pages_total=snap["pages_total"])

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        """Expected service seconds from the engine's measured prefill and
        decode throughput (wall time spent inside the respective jit calls,
        so admission/sampling overhead does not skew the rates)."""
        st = self.engine.stats
        if st.decode_tokens == 0 or st.decode_wall_s <= 0:
            return float("inf")      # no calibration data yet: probe-unknown
        t = output_tokens / (st.decode_tokens / st.decode_wall_s)
        if st.prefill_tokens > 0 and st.prefill_wall_s > 0:
            t += prompt_tokens / (st.prefill_tokens / st.prefill_wall_s)
        return t

    # ---------------------------------------------------------------- driving
    def step(self) -> List[GenRequest]:
        finished = self.engine.step()
        for r in finished:
            if self._on_complete is not None:
                self._on_complete(r, r.started_at, r.first_token_at)
        return finished

    def drain(self) -> List[GenRequest]:
        done: List[GenRequest] = []
        while self.engine.has_work():
            done.extend(self.step())
        return done
