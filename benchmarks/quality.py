"""Fig 6: quality incentivization — credit dynamics under heterogeneous nodes.

Four controlled experiments, three node classes x two replicas each:
(a) model capacity (Qwen3 8B/4B/0.6B), (b) quantization (fp8wo/int4wo-128/
int4wo-32), (c) serving backend (flashinfer/triton/sdpa), (d) hardware
(A100/RTX4090/RTX3090).  Requests come from a dedicated requester-only node.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import DuelParams, Network, Node, NodePolicy
from repro.sim import WorkloadSpec, make_profile, make_requests, uniform_phases
from repro.sim.servicemodel import (MODEL_QUALITY, QUANT_QUALITY_DELTA,
                                    make_profile as mk)

T_END = 1500.0

EXPERIMENTS: Dict[str, List[Tuple[str, dict]]] = {
    "model_capacity": [
        ("qwen3-8b", dict(model="qwen3-8b")),
        ("qwen3-4b", dict(model="qwen3-4b")),
        ("qwen3-0.6b", dict(model="qwen3-0.6b")),
    ],
    "quantization": [
        ("fp8wo", dict(model="qwen3-8b", quant="fp8wo")),
        ("int4wo-128", dict(model="qwen3-8b", quant="int4wo-128")),
        ("int4wo-32", dict(model="qwen3-8b", quant="int4wo-32")),
    ],
    "backend": [
        ("flashinfer", dict(model="qwen3-8b", backend="flashinfer")),
        ("triton", dict(model="qwen3-8b", backend="triton")),
        ("sdpa", dict(model="qwen3-8b", backend="sdpa")),
    ],
    "hardware": [
        ("A100", dict(model="qwen3-8b", gpu="A100")),
        ("RTX4090", dict(model="qwen3-8b", gpu="RTX4090")),
        ("RTX3090", dict(model="qwen3-8b", gpu="RTX3090")),
    ],
}


def _quality(kw: dict) -> float:
    q = MODEL_QUALITY.get(kw.get("model", "qwen3-8b"), 0.5)
    q += QUANT_QUALITY_DELTA.get(kw.get("quant", "bf16"), 0.0)
    return float(np.clip(q, 0.05, 0.95))


def run_experiment(name: str, seed: int = 0) -> Dict:
    classes = EXPERIMENTS[name]
    net = Network(mode="decentralized", seed=seed, ledger_mode="shared",
                  duel=DuelParams(p_d=0.35, k_judges=2, r_add=3.0,
                                  penalty=3.0, judge_accuracy=0.9),
                  init_balance=2000.0)
    # requester-only node: fast profile but always offloads, never accepts
    req_pol = NodePolicy(offload_freq=1.0, accept_freq=0.0,
                         offload_queue_threshold=0, offload_util_threshold=0.0,
                         stake=1.0)
    net.add_node(Node("requester", mk("qwen3-8b", "A100", "sglang",
                                      quality=0.5), policy=req_pol))
    class_of: Dict[str, str] = {}
    for ci, (cname, kw) in enumerate(classes):
        for r in range(2):
            nid = f"{cname}-{r}"
            prof = mk(kw.get("model", "qwen3-8b"), kw.get("gpu", "A100"),
                      kw.get("backend", "sglang"), kw.get("quant", "bf16"),
                      quality=_quality(kw))
            pol = NodePolicy(offload_freq=0.0, accept_freq=1.0,
                             target_utilization=0.7)
            net.add_node(Node(nid, prof, policy=pol))
            class_of[nid] = cname
    specs = [WorkloadSpec("requester", uniform_phases(T_END, 0.5),
                          output_mean=2048, slo_s=600.0)]
    m = net.run(make_requests(specs, seed=11 + seed), until=T_END,
                trace_interval=30.0)

    out: Dict = {"experiment": name, "classes": {}}
    for cname, _ in classes:
        members = [n for n in class_of if class_of[n] == cname]
        credit = sum(net.ledger_balance(n) + net.shared_ledger.stake_of(n)
                     for n in members)
        credit -= sum(2000.0 + net.nodes[n].policy.stake for n in members)
        served = sum(net.nodes[n].served_total for n in members)
        wins = sum(net.nodes[n].duel_wins for n in members)
        losses = sum(net.nodes[n].duel_losses for n in members)
        winrate = wins / max(wins + losses, 1)
        out["classes"][cname] = {"credit": credit, "served": served,
                                 "win_rate": winrate}
    return out


def run_experiment_avg(name: str, seeds=(0, 1, 2)) -> Dict:
    """Average over seeds: single-run credit gaps are within duel noise
    (the paper uses 2 replicas per class for the same reason)."""
    acc: Dict = {"experiment": name, "classes": {}}
    for s in seeds:
        r = run_experiment(name, seed=s)
        for c, v in r["classes"].items():
            slot = acc["classes"].setdefault(
                c, {"credit": 0.0, "served": 0, "win_rate": 0.0})
            slot["credit"] += v["credit"] / len(seeds)
            slot["served"] += v["served"] // len(seeds)
            slot["win_rate"] += v["win_rate"] / len(seeds)
    return acc


def main(rows: List[str]) -> None:
    for name in EXPERIMENTS:
        t0 = time.perf_counter()
        r = run_experiment_avg(name)
        us = (time.perf_counter() - t0) * 1e6
        cs = r["classes"]
        parts = [f"{c}:credit={v['credit']:.0f}:served={v['served']}"
                 f":win={v['win_rate']:.2f}" for c, v in cs.items()]
        order = list(cs)
        credits = [cs[c]["credit"] for c in order]
        mono = all(credits[i] >= credits[i + 1] for i in
                   range(len(credits) - 1))
        rows.append(f"fig6_{name},{us:.0f},{';'.join(parts)}"
                    f";ordered={mono}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
