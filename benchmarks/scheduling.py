"""Fig 4 + Table 2: SLO attainment and latency, single vs centralized vs
WWW.Serve (decentralized) across Settings 1-4."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.settings import SETTINGS, T_END, build_network
from repro.sim import make_requests

SLO_SCALES = (0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0)


def run_setting(setting: str, seed: int = 0) -> Dict:
    out: Dict = {"setting": setting}
    for mode in ("single", "centralized", "decentralized"):
        net, specs = build_network(setting, mode, seed=seed)
        reqs = make_requests(specs, seed=42 + seed)
        t0 = time.perf_counter()
        m = net.run(reqs, until=T_END)
        out[mode] = {
            "slo": m.slo_attainment(),
            "slo_curve": m.slo_curve(SLO_SCALES),
            "avg_latency": m.avg_latency(),
            "p90_latency": m.latency_percentile(90),
            "p95_latency": m.latency_percentile(95),
            "avg_ttft": m.avg_ttft(),
            "delegation_rate": m.delegation_rate(),
            "n": len([c for c in m.completed if not c.is_duel_extra]),
            "wall_s": time.perf_counter() - t0,
        }
    return out


def main(rows: List[str]) -> None:
    for setting in SETTINGS:
        t0 = time.perf_counter()
        r = run_setting(setting)
        us = (time.perf_counter() - t0) * 1e6
        single, cent, dec = r["single"], r["centralized"], r["decentralized"]
        ratio = dec["slo"] / max(single["slo"], 1e-9)
        # paper: "up to 1.5x" appears at tight latency thresholds
        ratio_max = max(d / max(s, 1e-9) for (_, d), (_, s) in
                        zip(dec["slo_curve"], single["slo_curve"]))
        lat_gain = 1 - dec["avg_latency"] / single["avg_latency"]
        rows.append(
            f"fig4_tab2_{setting},{us:.0f},"
            f"slo_single={single['slo']:.3f};slo_central={cent['slo']:.3f};"
            f"slo_dec={dec['slo']:.3f};slo_ratio={ratio:.2f};"
            f"slo_ratio_max={ratio_max:.2f};"
            f"lat_single={single['avg_latency']:.1f};"
            f"lat_central={cent['avg_latency']:.1f};"
            f"lat_dec={dec['avg_latency']:.1f};lat_gain={lat_gain:.3f}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
