"""kernel-lint: hygiene for Pallas kernel bodies and their wrappers.

A Pallas kernel body is traced once and compiled; Python-level effects
inside it either disappear silently or poison the trace.  This checker
finds every kernel body reachable from a ``pl.pallas_call`` (resolving
``functools.partial(kernel, ...)`` bindings) and enforces
(DESIGN.md §7):

* ``kernel-lint/side-effects`` — no host-side calls in a kernel body:
  ``print``/``breakpoint``/``input``/``open``/``exec``/``eval``, host
  ``numpy`` (``np.*``) ops, and no ``global``/``nonlocal`` statements.
* ``kernel-lint/closure`` — the kernel body must not capture names from
  an enclosing function scope.  Closure capture is how tracers leak into
  a kernel (the wrapper's arrays are visible to a nested def); static
  values must be bound explicitly via ``functools.partial`` keywords so
  they are parameters, not ambient state.  Module-level kernels with
  module-global references are fine.
* ``kernel-lint/index-map`` — BlockSpec ``index_map`` callables must be
  pure index arithmetic: single-expression bodies, no assignments, and no
  calls beyond ``pl.ds``/``pl.dslice``/``pl.multiple_of`` and
  ``min``/``max``/``divmod``.  (Scalar-prefetch ref reads are
  subscripts, not calls, and stay legal.)
* ``kernel-lint/grid-divisibility`` — a grid axis computed as ``x // b``
  silently drops remainder tokens when ``b`` does not divide ``x``.  The
  wrapper must carry evidence of divisibility for each such divisor:
  either pad arithmetic mentioning ``% b`` or an
  ``assert ... % b == 0``.  Divisors may be plain names or dotted
  attributes — a tunable ``// tuning.pages_per_step`` needs the same
  ``% tuning.pages_per_step`` evidence as a literal block size.
* ``kernel-lint/dequant-import`` — a module that builds Pallas calls and
  touches quantized KV must IMPORT ``kv_quantize``/``kv_dequantize``
  from ``repro.models.attention``, never re-define them: the pack/unpack
  convention (per-token-per-head scales, trailing 1-dim) is a cross-layer
  contract with the page pools and the oracles, and a local copy drifts
  silently.  (Checked module-wide because kernel bodies are often passed
  as parameters, which resolution cannot chase.)
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.astutil import (FunctionIndex, assigned_names,
                                    call_name, module_scope_names,
                                    numpy_aliases, param_names)
from repro.analysis.framework import Checker, Finding, RepoIndex, register

FORBIDDEN_CALLS = frozenset({"print", "breakpoint", "input", "open",
                             "exec", "eval"})
INDEX_MAP_CALL_WHITELIST = frozenset({"ds", "dslice", "multiple_of",
                                      "min", "max", "divmod"})
# quantized-KV pack/unpack helpers: single source of truth for the scale
# layout, shared by kernels, oracles, and the page pools
DEQUANT_HELPERS = frozenset({"kv_quantize", "kv_dequantize"})
ATTENTION_MODULE = "repro.models.attention"


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _is_pallas_call(node: ast.Call) -> bool:
    name = call_name(node)
    return name is not None and name.split(".")[-1] == "pallas_call"


def _is_blockspec(node: ast.Call) -> bool:
    name = call_name(node)
    return name is not None and name.split(".")[-1] == "BlockSpec"


def _is_partial(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call) and call_name(node) is not None
            and call_name(node).split(".")[-1] == "partial")


@register
class KernelLintChecker(Checker):
    rule_id = "kernel-lint"
    description = ("Pallas kernel bodies: no host side effects, no "
                   "closure capture, pure index maps, guarded grid "
                   "divisions")

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        for rel in repo.py_files():
            tree = repo.tree(rel)
            if tree is None:
                continue
            text = repo.text(rel)
            if "pallas_call" not in text and "BlockSpec" not in text:
                continue                      # cheap pre-filter
            yield from self._check_module(rel, tree)
            if "pallas_call" in text:
                yield from self._check_dequant_imports(rel, tree)

    # ------------------------------------------------------------ plumbing
    def _check_module(self, rel: str, tree: ast.Module) -> List[Finding]:
        out: List[Finding] = []
        fidx = FunctionIndex(tree)
        mod_names = module_scope_names(tree)
        np_names = numpy_aliases(tree)

        # wrapper function -> its local name->value assignments (for
        # resolving `kernel = functools.partial(_body, ...)` and
        # `grid = (...)` indirections)
        def local_assigns(fn: ast.FunctionDef) -> Dict[str, ast.AST]:
            binds: Dict[str, ast.AST] = {}
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    binds[node.targets[0].id] = node.value
            return binds

        def nested_defs(fn: ast.FunctionDef) -> Dict[str, ast.FunctionDef]:
            return {n.name: n for n in ast.walk(fn)
                    if isinstance(n, ast.FunctionDef) and n is not fn}

        def resolve_fn(node: ast.AST, binds: Dict[str, ast.AST],
                       wrapper: Optional[ast.FunctionDef]):
            """Follow Name -> assignment -> functools.partial -> def,
            checking wrapper-local (nested) defs before module scope."""
            inner = nested_defs(wrapper) if wrapper is not None else {}
            for _ in range(4):                 # bounded chase
                if isinstance(node, ast.Name):
                    if node.id in binds:
                        node = binds[node.id]
                    elif node.id in inner:
                        return inner[node.id]
                    elif node.id in fidx.module_level:
                        return fidx.module_level[node.id]
                    else:
                        return None
                elif _is_partial(node):
                    node = node.args[0] if node.args else None
                elif isinstance(node, ast.Lambda):
                    return node
                elif isinstance(node, ast.FunctionDef):
                    return node
                else:
                    return None
            return None

        for wrapper in fidx.module_level.values():
            binds = local_assigns(wrapper)
            for node in ast.walk(wrapper):
                if not isinstance(node, ast.Call):
                    continue
                if _is_pallas_call(node):
                    kernel = resolve_fn(node.args[0], binds, wrapper) \
                        if node.args else None
                    if isinstance(kernel, ast.FunctionDef):
                        out.extend(self._check_kernel_body(
                            rel, kernel, fidx, mod_names, np_names))
                    out.extend(self._check_grid(rel, node, binds, wrapper))
                elif _is_blockspec(node):
                    imap = None
                    if len(node.args) >= 2:
                        imap = node.args[1]
                    for kw in node.keywords:
                        if kw.arg == "index_map":
                            imap = kw.value
                    if imap is not None:
                        fn = resolve_fn(imap, binds, wrapper)
                        if fn is not None:
                            out.extend(self._check_index_map(rel, fn))
        return out

    # --------------------------------------------------------- kernel body
    def _check_kernel_body(self, rel: str, fn: ast.FunctionDef,
                           fidx: FunctionIndex, mod_names: Set[str],
                           np_names: Set[str]) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                out.append(Finding(
                    "kernel-lint/side-effects", rel, node.lineno,
                    f"'{'global' if isinstance(node, ast.Global) else 'nonlocal'}'"
                    f" inside Pallas kernel '{fn.name}' (kernel bodies "
                    f"must be effect-free)"))
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name is None:
                    continue
                root, leaf = name.split(".")[0], name.split(".")[-1]
                if name in FORBIDDEN_CALLS or leaf == "breakpoint":
                    out.append(Finding(
                        "kernel-lint/side-effects", rel, node.lineno,
                        f"host-side call '{name}' inside Pallas kernel "
                        f"'{fn.name}' (traced once, then silent — use "
                        f"pl.debug_print or lift it out)"))
                elif root in np_names:
                    out.append(Finding(
                        "kernel-lint/side-effects", rel, node.lineno,
                        f"host numpy call '{name}' inside Pallas kernel "
                        f"'{fn.name}' (use jnp — numpy executes at trace "
                        f"time on the host)"))

        # closure capture: free names of the kernel must resolve to module
        # scope, not to an enclosing function's locals (tracer hazard)
        parent = fidx.parent.get(fn)
        if parent is not None:
            local = param_names(fn) | assigned_names(fn)
            outer = (param_names(parent) | assigned_names(parent)) - local
            free = {n.id for n in ast.walk(fn)
                    if isinstance(n, ast.Name)
                    and isinstance(n.ctx, ast.Load)} - local - mod_names
            captured = sorted(free & outer)
            if captured:
                out.append(Finding(
                    "kernel-lint/closure", rel, fn.lineno,
                    f"Pallas kernel '{fn.name}' captures "
                    f"{', '.join(captured)} from the enclosing function "
                    f"scope; bind statics via functools.partial keywords "
                    f"instead (closure capture is how tracers leak in)"))
        return out

    # ----------------------------------------------------------- index map
    def _check_index_map(self, rel: str, fn) -> List[Finding]:
        out: List[Finding] = []
        if isinstance(fn, ast.Lambda):
            body_stmts: List[ast.AST] = []
            exprs: List[ast.AST] = [fn.body]
        else:
            body_stmts = list(fn.body)
            # tolerate a leading docstring
            if body_stmts and isinstance(body_stmts[0], ast.Expr) \
                    and isinstance(body_stmts[0].value, ast.Constant) \
                    and isinstance(body_stmts[0].value.value, str):
                body_stmts = body_stmts[1:]
            exprs = [s.value for s in body_stmts
                     if isinstance(s, ast.Return) and s.value is not None]
            impure = [s for s in body_stmts if not isinstance(s, ast.Return)]
            if impure:
                out.append(Finding(
                    "kernel-lint/index-map", rel, impure[0].lineno,
                    f"index_map '{getattr(fn, 'name', '<lambda>')}' has "
                    f"non-return statements; index maps must be pure "
                    f"index arithmetic"))
        for expr in exprs:
            for node in ast.walk(expr):
                if isinstance(node, ast.Call):
                    name = call_name(node) or "<dynamic>"
                    if name.split(".")[-1] not in INDEX_MAP_CALL_WHITELIST:
                        out.append(Finding(
                            "kernel-lint/index-map", rel, node.lineno,
                            f"index_map calls '{name}'; only "
                            f"{sorted(INDEX_MAP_CALL_WHITELIST)} are "
                            f"recognized as pure index arithmetic"))
        return out

    # -------------------------------------------------- grid divisibility
    def _check_grid(self, rel: str, call: ast.Call,
                    binds: Dict[str, ast.AST],
                    wrapper: ast.FunctionDef) -> List[Finding]:
        out: List[Finding] = []
        grid_nodes: List[ast.AST] = []
        for kw in call.keywords:
            if kw.arg in ("grid", "grid_spec"):
                grid_nodes.append(kw.value)
        resolved: List[ast.AST] = []
        for g in grid_nodes:
            if isinstance(g, ast.Name) and g.id in binds:
                g = binds[g.id]
            if isinstance(g, ast.Call):       # GridSpec(...)-style wrapper
                inner = [kw.value for kw in g.keywords if kw.arg == "grid"]
                for node in inner:
                    if isinstance(node, ast.Name) and node.id in binds:
                        node = binds[node.id]
                    resolved.append(node)
            else:
                resolved.append(g)

        # divisibility evidence available in this wrapper, per divisor name
        # (plain or dotted: a tunable '// tuning.pages_per_step' needs
        # '% tuning.pages_per_step' evidence just like a literal block size)
        evidence: Set[str] = set()
        for node in ast.walk(wrapper):
            if isinstance(node, (ast.Assign, ast.Assert)):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.BinOp) \
                            and isinstance(sub.op, ast.Mod):
                        name = _dotted(sub.right)
                        if name is not None:
                            evidence.add(name)

        for g in resolved:
            if not isinstance(g, (ast.Tuple, ast.List)):
                continue
            for dim in g.elts:
                if not (isinstance(dim, ast.BinOp)
                        and isinstance(dim.op, ast.FloorDiv)):
                    continue
                divisor = _dotted(dim.right)
                if divisor is not None and divisor not in evidence:
                    out.append(Finding(
                        "kernel-lint/grid-divisibility", rel, dim.lineno,
                        f"grid axis floor-divides by '{divisor}' "
                        f"with no divisibility evidence in "
                        f"'{wrapper.name}' (pad with '% "
                        f"{divisor}' arithmetic or assert "
                        f"'.. % {divisor} == 0' — a non-dividing "
                        f"block silently drops tokens)"))
        return out

    # ------------------------------------------------------ dequant imports
    def _check_dequant_imports(self, rel: str,
                               tree: ast.Module) -> List[Finding]:
        """Module-wide (kernel bodies are routinely passed as parameters,
        so per-kernel resolution cannot see them): in a module that builds
        ``pallas_call``s, the quantized-KV helpers must come from
        ``repro.models.attention``."""
        out: List[Finding] = []
        imported: Set[str] = set()        # bare names bound by the import
        mod_aliases: Set[str] = set()     # module aliases for dotted calls
        for node in tree.body:
            if isinstance(node, ast.ImportFrom):
                if node.module == ATTENTION_MODULE:
                    imported |= {a.asname or a.name for a in node.names
                                 if a.name in DEQUANT_HELPERS}
                elif node.module == "repro.models":
                    mod_aliases |= {a.asname or a.name for a in node.names
                                    if a.name == "attention"}
            elif isinstance(node, ast.Import):
                mod_aliases |= {a.asname or a.name.split(".")[0]
                                for a in node.names
                                if a.name == ATTENTION_MODULE}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in DEQUANT_HELPERS:
                out.append(Finding(
                    "kernel-lint/dequant-import", rel, node.lineno,
                    f"'{node.name}' re-defined in a Pallas module; the "
                    f"quantized-KV pack/unpack convention lives in "
                    f"{ATTENTION_MODULE} — import it (a local copy "
                    f"drifts from the pools and oracles silently)"))
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name is None or name.split(".")[-1] not in DEQUANT_HELPERS:
                    continue
                ok = (name in imported if "." not in name
                      else name.split(".")[0] in mod_aliases)
                if not ok:
                    out.append(Finding(
                        "kernel-lint/dequant-import", rel, node.lineno,
                        f"call to '{name}' does not resolve to an import "
                        f"from {ATTENTION_MODULE}; the scale layout is a "
                        f"cross-layer contract — import the shared helper"))
        return out
