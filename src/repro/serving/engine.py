"""A small batched serving engine — the node's Model Manager backend.

Real (not simulated) JAX inference with **slot-based continuous batching**
(DESIGN.md §6.1): the engine keeps a persistent decode cache with
``max_batch`` row slots, each resident sequence decoding at its own depth
(per-row cache lengths).  After every decode step finished sequences are
evicted and queued requests are prefilled into the freed slots — a short
request no longer holds the batch hostage for the longest request's budget.
Prompts are right-padded, which causal attention keeps inert, so a request's
greedy output is independent of what it happens to be batched with (wave
batching, ``continuous=False``, produces bit-identical greedy results in
more decode steps).

``Engine(paged=True)`` swaps the per-slot contiguous cache for a **paged KV
cache** (DESIGN.md §6.1, paged backend): a fixed pool of page-sized KV
blocks with a per-sequence block table, grown one page at a time during
decode.  Admission charges a request's *prompt* pages only (not
``prompt + max_new`` as the contiguous slot cache must reserve), finished
sequences return their pages to the pool, and when the pool exhausts
mid-decode the most recently admitted sequence is preempted — its pages
reclaimed, its request requeued at the head of the queue for a greedy-
deterministic restart.  Greedy outputs stay bit-identical to the slot and
wave paths while strictly more requests are resident on the same KV budget.

This is the backend used by the runnable examples and the end-to-end
decentralized serving driver (``repro.launch.serve``, via
``repro.serving.executor.EngineExecutor``); the large-scale scheduling
benchmarks use the simulated executor instead (see DESIGN.md §6.1).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig
from repro.serving.sampling import sample
from repro.sim.executor import paged_admit_ok, pages_for


@dataclass
class GenRequest:
    rid: str
    tokens: np.ndarray            # (S,) prompt token ids
    max_new: int = 32
    temperature: float = 0.0
    result: Optional[np.ndarray] = None
    # engine metrics (wall-clock)
    enqueued_at: float = 0.0
    started_at: float = 0.0       # admitted into a slot (prefill)
    first_token_at: float = 0.0   # first output token sampled
    finished_at: float = 0.0


@dataclass
class EngineStats:
    served: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    batches: int = 0              # prefill batches
    decode_steps: int = 0         # batched decode_step invocations
    prefill_wall_s: float = 0.0   # wall time inside prefill calls
    decode_wall_s: float = 0.0    # wall time inside decode_step calls
    peak_resident: int = 0        # max concurrently resident sequences
    preempted: int = 0            # paged: preempt-and-requeue events
    handoffs: int = 0             # disagg: KV handoffs extracted/accepted
    handoff_bytes: int = 0        # disagg: valid KV bytes handed off


@dataclass
class KVHandoff:
    """A prefilled request leaving a disaggregated prefill engine
    (DESIGN.md §6.1-disagg): its populated KV pages, the tokens it has
    already sampled (the prefill side emits the first token), and the
    next-token logits the decode side resumes from.  ``k``/``v`` are
    page-granular copies — the prefill engine's physical pages are released
    the moment the handoff is extracted; the decode engine scatters them
    into its own pool under fresh page numbers (``Engine.accept_handoff``).
    """

    req: GenRequest
    out: List[int]                # tokens sampled on the prefill side (>= 1)
    length: int                   # valid KV tokens: prompt + len(out)
    k: "jax.Array"                # (L, n_pages, page, Hkv, dh)
    v: "jax.Array"
    logits: "jax.Array"           # (1, V) next-token logits
    page_size: int

    @property
    def kv_bytes(self) -> int:
        """Bytes of *valid* KV crossing the wire — the sim's transfer cost
        model charges the same quantity (prompt-dominated: len(out) is 1
        unless the prefill side raced ahead)."""
        n_layers, _, _, n_kv, dh = self.k.shape
        return 2 * n_layers * self.length * n_kv * dh * self.k.dtype.itemsize


class _Slot:
    """One resident sequence: its request, sampled tokens, cache depth."""

    __slots__ = ("req", "out")

    def __init__(self, req: GenRequest) -> None:
        self.req = req
        self.out: List[int] = []


class Engine:
    """Persistent-slot continuous batching with a jitted step per bucket."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 bucket: int = 64, seed: int = 0,
                 capacity: Optional[int] = None,
                 continuous: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.continuous = continuous
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        fam = registry.get_family(cfg)
        # right-padding is only inert with a full cache: a sliding-window
        # ring keeps the last `window` positions of the PADDED sequence, so
        # trailing pads would evict real in-window KV — window configs stay
        # on the left-padded lock-step wave path
        self.slot_decode = fam.slot_decode and cfg.sliding_window is None
        if self.slot_decode:
            self._prefill = jax.jit(
                lambda p, b, cap, lp: fam.prefill(p, cfg, b, q_chunk=256,
                                                  kv_chunk=256, capacity=cap,
                                                  last_positions=lp),
                static_argnums=(2,))
        else:
            # families without per-row cache depths fall back to left-padded
            # lock-step wave batching
            self._prefill = jax.jit(
                lambda p, b, cap: fam.prefill(p, cfg, b, q_chunk=256,
                                              kv_chunk=256, capacity=cap),
                static_argnums=(2,))
        self._decode = jax.jit(lambda p, c, t: fam.decode_step(p, cfg, c, t))
        self.eos_id = cfg.eos_id

        # persistent slot state
        self._queue: List[GenRequest] = []
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._lengths = np.zeros(max_batch, np.int64)   # per-row cache depth
        self._cache: Optional[Dict] = None
        self._logits: Optional[jax.Array] = None
        self._capacity = int(capacity or 0)

        # paged-KV state (DESIGN.md §6.1, paged backend)
        self.paged = bool(paged)
        self.page_size = int(page_size)
        if self.paged:
            if not (self.slot_decode and fam.paged_decode is not None):
                raise ValueError(
                    "paged KV requires a paged-capable slot-decode family "
                    "(dense/vlm with full attention)")
            if cfg.kv_quant:
                raise ValueError("paged KV does not support kv_quant caches")
            self._decode_paged = jax.jit(
                lambda p, c, t: fam.paged_decode(p, cfg, c, t))
            self._scatter_pages = jax.jit(fam.prefill_to_pages)
            self._init_pools = fam.init_paged_pools
            usable = (int(num_pages) if num_pages is not None
                      else max_batch * pages_for(2 * bucket, self.page_size))
            self._num_pages = usable + 1          # page 0 is scratch
            self._pools: Optional[Dict] = None    # lazy device alloc
            self._free_pages: List[int] = list(range(1, self._num_pages))
            self._row_pages: List[List[int]] = [[] for _ in range(max_batch)]
            self._maxp = max(1, pages_for(2 * bucket, self.page_size))
            self._block_tables = np.zeros((max_batch, self._maxp), np.int32)
            # admission order, for LIFO preemption under pool pressure
            self._slot_seq = np.zeros(max_batch, np.int64)
            self._admit_seq = 0

    def _pad_bucket(self, n: int) -> int:
        b = self.bucket
        return max(b, (n + b - 1) // b * b)

    def _required(self, r: GenRequest) -> int:
        return self._pad_bucket(len(r.tokens)) + self._pad_bucket(r.max_new)

    # ------------------------------------------------------------- interface
    def submit(self, r: GenRequest) -> None:
        r.enqueued_at = time.perf_counter()
        self._queue.append(r)

    def requeue(self, r: GenRequest) -> None:
        """Put a preempted/rerouted request back at the head of the queue
        WITHOUT re-stamping ``enqueued_at`` — its queue wait keeps counting
        from the original submission, so ``queue_wait`` stays monotone
        across preemption round-trips (the disagg executor routes
        decode-side preemptions back through the prefill engine)."""
        self._queue.insert(0, r)

    def take_queued(self) -> List[GenRequest]:
        """Drain and return the queue (admission re-routing: the disagg
        executor uses this to pull decode-side preemptions back out, since
        handoffs never travel through the decode engine's own queue)."""
        q, self._queue = self._queue, []
        return q

    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    def active_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    def queued(self) -> int:
        return len(self._queue)

    def load_snapshot(self) -> Dict[str, int]:
        """Occupancy counts for Executor.load() — the supported view of the
        slot/queue/page-pool bookkeeping (token counts are *remaining* work;
        this dict, not the private pool state, is the sanctioned external
        view — a grep-guard in tests/test_compat.py enforces it)."""
        active = [(i, s) for i, s in enumerate(self._slots) if s is not None]
        snap = dict(
            active_streams=len(active),
            queued_streams=len(self._queue),
            queued_prompt_tokens=sum(len(r.tokens) for r in self._queue),
            queued_new_tokens=sum(r.max_new for r in self._queue),
            pending_decode_tokens=sum(s.req.max_new - len(s.out)
                                      for _, s in active),
            pages_used=0, pages_total=0, free_pages=0, page_size=0)
        if self.paged:
            usable = self._num_pages - 1
            used = usable - len(self._free_pages)
            snap.update(
                pages_used=used, pages_total=usable,
                free_pages=len(self._free_pages), page_size=self.page_size,
                # paged KV charges pages actually held, not reservations
                kv_used=used * self.page_size,
                kv_budget=usable * self.page_size)
        else:
            snap.update(
                kv_used=int(sum(self._lengths[i] + s.req.max_new - len(s.out)
                                for i, s in active)),
                kv_budget=self.max_batch * max(self._capacity, 1))
        return snap

    def serve(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Submit ``reqs`` and pump steps until the engine drains."""
        if not self.slot_decode:
            return self._serve_wave_legacy(reqs)
        for r in reqs:
            self.submit(r)
        while self.has_work():
            self.step()
        return reqs

    def generate_batch(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Serve up to max_batch requests together; returns them completed."""
        assert len(reqs) <= self.max_batch
        return self.serve(reqs)

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        if self.paged:
            self._admit_paged()
            return
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        if resident and any(self._required(r) > self._capacity
                            for r in self._queue):
            # a queued request needs a bigger cache, which can only be
            # allocated while nothing is resident: stop backfilling so the
            # batch drains and the growth branch below runs (otherwise a
            # steady stream of small requests starves the big one forever)
            return
        if not resident:
            # grow the cache while nothing is resident (allocation is static
            # under jit, so capacity only changes between generations)
            needed = max(self._required(r)
                         for r in self._queue[:self.max_batch])
            if self._cache is None or needed > self._capacity:
                self._capacity = max(self._capacity, needed)
                self._cache = None
                self._logits = None
        free = [i for i, s in enumerate(self._slots) if s is None]
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        for r in self._queue:
            # skip requests the current cache can't hold; they are admitted
            # at the next idle point, when capacity can grow
            if free and self._required(r) <= self._capacity:
                take.append((free.pop(0), r))
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._prefill_into(take)

    def _prefill_into(self, take: List[Tuple[int, GenRequest]]) -> None:
        n = len(take)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        for j, (_, r) in enumerate(take):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      self._capacity, jnp.asarray(last))
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * n
        self.stats.batches += 1
        kv = {k: v for k, v in cache.items() if k != "length"}
        rows = jnp.asarray([i for i, _ in take])
        if self._cache is None:
            self._cache = jax.tree_util.tree_map(
                lambda leaf: jnp.zeros(
                    (leaf.shape[0], self.max_batch) + leaf.shape[2:],
                    leaf.dtype), kv)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._cache = jax.tree_util.tree_map(
            lambda p, nw: p.at[:, rows].set(nw), self._cache, kv)
        self._logits = self._logits.at[rows].set(logits)
        now = time.perf_counter()
        for i, r in take:
            r.started_at = now
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())

    # -------------------------------------------------------- paged admission
    def _pages(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def _admit_paged(self) -> None:
        if not self._queue:
            return
        resident = any(s is not None for s in self._slots)
        if not self.continuous and resident:
            return                     # wave batching: refill only when empty
        usable = self._num_pages - 1
        if resident and any(self._pages(self._required(r)) > usable
                            for r in self._queue):
            # a queued request cannot fit the pool even alone; stop
            # backfilling so the batch drains and the growth branch runs
            return
        if not resident:
            # grow the pool while nothing is resident, so any single admitted
            # request can always run to completion (its worst-case pages fit
            # the pool) — this is what makes LIFO preemption livelock-free
            needed = max(self._pages(self._required(r))
                         for r in self._queue[:self.max_batch])
            if self._pools is None or needed > usable:
                self._num_pages = max(self._num_pages, needed + 1)
                usable = self._num_pages - 1
                self._pools = None
                self._logits = None
                self._free_pages = list(range(1, self._num_pages))
        free_slots = [i for i, s in enumerate(self._slots) if s is None]
        free_now = len(self._free_pages)
        take: List[Tuple[int, GenRequest]] = []
        rest: List[GenRequest] = []
        taking = resident
        for r in self._queue:
            need = self._pages(len(r.tokens))
            if (free_slots and need <= free_now
                    and self._pages(self._required(r)) <= usable
                    and paged_admit_ok(free_now, len(r.tokens),
                                       self.page_size, resident=taking)):
                take.append((free_slots.pop(0), r))
                free_now -= need
                taking = True
            else:
                rest.append(r)
        self._queue = rest
        if take:
            self._grow_block_tables(max(self._pages(self._required(r))
                                        for _, r in take))
            self._prefill_paged(take)

    def _grow_block_tables(self, maxp: int) -> None:
        if maxp <= self._maxp:
            return
        wider = np.zeros((self.max_batch, maxp), np.int32)
        wider[:, : self._maxp] = self._block_tables
        self._block_tables = wider
        self._maxp = maxp

    def _prefill_paged(self, take: List[Tuple[int, GenRequest]]) -> None:
        """Right-padded prompt prefill, then scatter the contiguous KV into
        freshly allocated pool pages (pad-tail pages alias the scratch page
        0, which per-row lengths keep inert)."""
        n = len(take)
        plen = self._pad_bucket(max(len(r.tokens) for _, r in take))
        plen = -(-plen // self.page_size) * self.page_size  # page multiple
        toks = np.full((n, plen), self.eos_id, np.int32)
        last = np.zeros(n, np.int32)
        phys = np.zeros((n, plen // self.page_size), np.int32)
        for j, (i, r) in enumerate(take):
            toks[j, : len(r.tokens)] = r.tokens      # right-pad (inert)
            last[j] = len(r.tokens) - 1
            pages = [self._free_pages.pop() for _ in
                     range(self._pages(len(r.tokens)))]
            self._row_pages[i] = pages
            phys[j, : len(pages)] = pages
            self._block_tables[i, :] = 0
            self._block_tables[i, : len(pages)] = pages
            self._slots[i] = _Slot(r)
            self._lengths[i] = len(r.tokens)
            self._slot_seq[i] = self._admit_seq
            self._admit_seq += 1
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                      plen, jnp.asarray(last))
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        now = time.perf_counter()       # started_at matches the slot path:
        for _, r in take:               # stamped after prefill completes
            r.started_at = now
        self.stats.prefill_tokens += plen * n
        self.stats.batches += 1
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())
        kv = {k: v for k, v in cache.items() if k != "length"}
        if self._pools is None:
            self._pools = self._init_pools(self.cfg, self._num_pages,
                                           self.page_size)
            self._logits = jnp.zeros((self.max_batch, 1, logits.shape[-1]),
                                     logits.dtype)
        self._pools = self._scatter_pages(self._pools, kv, jnp.asarray(phys))
        rows = jnp.asarray([i for i, _ in take])
        self._logits = self._logits.at[rows].set(logits)

    # ----------------------------------------------------- page pool dynamics
    def _release_pages(self, i: int) -> None:
        self._free_pages.extend(self._row_pages[i])
        self._row_pages[i] = []
        self._block_tables[i, :] = 0

    def _preempt(self, i: int) -> None:
        """Reclaim row ``i``'s pages and requeue its request at the head of
        the queue (vLLM-style recompute preemption: generated tokens are
        discarded; the greedy restart reproduces them bit-identically).

        The admission clocks are reset along with the discarded tokens:
        ``started_at``/``first_token_at`` belong to the aborted attempt, so
        leaving them set would let a mid-flight reader (metrics scrape, the
        disagg executor re-routing the request) report a TTFT for tokens
        the user never kept.  The restart re-stamps both, which also keeps
        ``enqueued_at <= started_at <= first_token_at <= finished_at``
        monotone on the completion record."""
        r = self._slots[i].req
        r.result = None
        r.started_at = 0.0
        r.first_token_at = 0.0
        self._release_pages(i)
        self._slots[i] = None
        self._lengths[i] = 0
        self._queue.insert(0, r)
        self.stats.preempted += 1

    def _ensure_decode_pages(self, survivors: List[int]) -> List[int]:
        """Allocate this step's write page for every surviving row (needed
        when its next token crosses a page boundary).  Under pool pressure
        the most recently admitted resident is preempted until a page frees;
        oldest rows are served first, so the oldest admission always makes
        progress and the preemption loop terminates."""
        for i in sorted(survivors, key=lambda i: self._slot_seq[i]):
            while (self._slots[i] is not None
                   and self._lengths[i] // self.page_size
                   >= len(self._row_pages[i])):
                if self._free_pages:
                    pg = self._free_pages.pop()
                    self._row_pages[i].append(pg)
                    idx = len(self._row_pages[i]) - 1
                    self._grow_block_tables(idx + 1)
                    self._block_tables[i, idx] = pg
                else:
                    victims = [j for j, s in enumerate(self._slots)
                               if s is not None]
                    self._preempt(max(victims, key=lambda j:
                                      self._slot_seq[j]))
        return [i for i in survivors if self._slots[i] is not None]

    # ------------------------------------------- disaggregated KV handoff
    # (DESIGN.md §6.1-disagg) — both ends live here because the page pool,
    # block tables, and free list are private to the engine (grep-guarded).

    def extract_handoffs(self) -> List[KVHandoff]:
        """Disagg prefill side: pop every resident row that has sampled at
        least one token as a ``KVHandoff`` and release its local pages.

        Driven after each ``step()`` of a prefill-role engine: a freshly
        admitted row samples its first token and decodes it (writing its KV)
        within that same step, so no row ever survives two steps here — the
        prefill engine's pool only ever holds prompts mid-prefill.  The
        gathered ``k``/``v`` are copies, which is what the simulated
        transfer cost model charges for.
        """
        assert self.paged, "KV handoff requires the paged backend"
        out: List[KVHandoff] = []
        for i, s in enumerate(self._slots):
            if s is None or not s.out:
                continue
            pages = jnp.asarray(self._row_pages[i], jnp.int32)
            h = KVHandoff(
                req=s.req, out=list(s.out), length=int(self._lengths[i]),
                k=self._pools["k_pool"][:, pages],
                v=self._pools["v_pool"][:, pages],
                logits=self._logits[i], page_size=self.page_size)
            self._release_pages(i)
            self._slots[i] = None
            self._lengths[i] = 0
            self.stats.handoffs += 1
            self.stats.handoff_bytes += h.kv_bytes
            out.append(h)
        return out

    def accept_handoff(self, h: KVHandoff) -> bool:
        """Disagg decode side: allocate pages for a handed-off request,
        scatter its KV into this engine's pool, and install it in a free
        slot with its prefill logits — decode resumes exactly where the
        prefill engine stopped, so greedy outputs stay bit-identical to a
        colocated paged engine.  Returns False (caller retries after a
        completion) when no slot or not enough free pages are available.
        """
        assert self.paged and h.page_size == self.page_size
        free_slots = [i for i, s in enumerate(self._slots) if s is None]
        if not free_slots:
            return False
        resident = any(s is not None for s in self._slots)
        usable = self._num_pages - 1
        worst = self._pages(self._required(h.req))
        if not resident:
            # grow the pool while nothing is resident (mirror _admit_paged)
            # so any single accepted handoff can always run to completion
            if self._pools is None or worst > usable:
                self._num_pages = max(self._num_pages, worst + 1)
                usable = self._num_pages - 1
                self._pools = None
                self._logits = None
                self._free_pages = list(range(1, self._num_pages))
        elif worst > usable:
            return False               # can never fit: wait for drain+growth
        need = pages_for(h.length, self.page_size)
        if need > len(self._free_pages):
            return False
        if self._pools is None:
            self._pools = self._init_pools(self.cfg, self._num_pages,
                                           self.page_size)
            self._logits = jnp.zeros(
                (self.max_batch, 1, h.logits.shape[-1]), h.logits.dtype)
        i = free_slots[0]
        pages = [self._free_pages.pop() for _ in range(need)]
        phys = jnp.asarray(pages, jnp.int32)
        self._pools = {
            "k_pool": self._pools["k_pool"].at[:, phys].set(h.k[:, :need]),
            "v_pool": self._pools["v_pool"].at[:, phys].set(h.v[:, :need])}
        self._grow_block_tables(max(need, worst))
        self._row_pages[i] = pages
        self._block_tables[i, :] = 0
        self._block_tables[i, :need] = pages
        slot = _Slot(h.req)
        slot.out = list(h.out)
        self._slots[i] = slot
        self._lengths[i] = h.length
        self._slot_seq[i] = self._admit_seq
        self._admit_seq += 1
        self._logits = self._logits.at[i].set(h.logits)
        self.stats.handoffs += 1
        self.stats.handoff_bytes += h.kv_bytes
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.active_slots())
        return True

    # ------------------------------------------------------------ decode step
    def step(self) -> List[GenRequest]:
        """One engine iteration: sample a token for every resident sequence,
        retire finished ones, prefill admissions into freed slots, then run
        one batched decode step for the sequences that continue."""
        if not self.slot_decode:
            return self._step_wave_legacy()
        self._admit()
        resident = [i for i, s in enumerate(self._slots) if s is not None]
        if not resident:
            return []
        # 1. sample next token for all resident rows from their current logits
        self.key, sk = jax.random.split(self.key)
        temps_np = np.zeros(self.max_batch, np.float32)
        for i in resident:
            temps_np[i] = self._slots[i].req.temperature
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        cur = sample(sk, self._logits, temperature=temps,
                     vocab_size=self.cfg.vocab_size)
        cur_np = np.asarray(cur[:, 0])
        now = time.perf_counter()
        finished: List[GenRequest] = []
        survivors: List[int] = []
        for i in resident:
            slot = self._slots[i]
            slot.out.append(int(cur_np[i]))
            if len(slot.out) == 1:
                slot.req.first_token_at = now
            hit_eos = cur_np[i] == self.eos_id
            if hit_eos or len(slot.out) >= slot.req.max_new:
                row = slot.out[:-1] if hit_eos and len(slot.out) > 1 \
                    else slot.out
                slot.req.result = np.asarray(row, np.int32)
                slot.req.finished_at = now
                finished.append(slot.req)
                self._slots[i] = None
                if self.paged:
                    self._release_pages(i)     # pages return to the pool
                self.stats.served += 1
            else:
                survivors.append(i)
        # 2. admit queued work into freed slots between decode steps
        if self.continuous and finished:
            self._admit()
        # 2b. paged: claim this step's write page per survivor, preempting
        #     the most recent admissions if the pool is exhausted
        if self.paged and survivors:
            survivors = self._ensure_decode_pages(survivors)
        # 3. one batched decode step advances the surviving rows; rows that
        #    were empty or just prefilled ride along (static batch shape) —
        #    their cache write lands at their own depth and is overwritten by
        #    their first real decode, and their logits are kept, not replaced
        if survivors:
            t0 = time.perf_counter()
            if self.paged:
                cache = {**self._pools,
                         "block_tables": jnp.asarray(self._block_tables),
                         "lengths": jnp.asarray(self._lengths, jnp.int32)}
                logits, cache = self._decode_paged(self.params, cache, cur)
                logits.block_until_ready()
                self._pools = {"k_pool": cache["k_pool"],
                               "v_pool": cache["v_pool"]}
            else:
                cache = {**self._cache,
                         "length": jnp.asarray(self._lengths, jnp.int32)}
                logits, cache = self._decode(self.params, cache, cur)
                logits.block_until_ready()
                self._cache = {k: v for k, v in cache.items()
                               if k != "length"}
            self.stats.decode_wall_s += time.perf_counter() - t0
            keep = jnp.asarray(survivors)
            self._logits = self._logits.at[keep].set(logits[keep])
            self._lengths[survivors] += 1
            self.stats.decode_tokens += len(survivors)
            self.stats.decode_steps += 1
        return finished

    # ----------------------------------------------- legacy wave (non-dense)
    def _step_wave_legacy(self) -> List[GenRequest]:
        if not self._queue:
            return []
        wave, self._queue = (self._queue[: self.max_batch],
                             self._queue[self.max_batch:])
        return self._generate_wave(wave)

    def _serve_wave_legacy(self, reqs: List[GenRequest]) -> List[GenRequest]:
        out: List[GenRequest] = []
        for i in range(0, len(reqs), self.max_batch):
            out.extend(self._generate_wave(reqs[i: i + self.max_batch]))
        return out

    def _generate_wave(self, reqs: List[GenRequest]) -> List[GenRequest]:
        """Left-padded lock-step decode for families without per-row cache
        depths (shared scalar cache length)."""
        assert len(reqs) <= self.max_batch
        max_prompt = max(len(r.tokens) for r in reqs)
        plen = self._pad_bucket(max_prompt)
        max_new = max(r.max_new for r in reqs)
        toks = np.full((len(reqs), plen), self.eos_id, np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.tokens):] = r.tokens     # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cap = plen + self._pad_bucket(max_new)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cap)
        logits.block_until_ready()
        self.stats.prefill_wall_s += time.perf_counter() - t0
        self.stats.prefill_tokens += plen * len(reqs)
        self.stats.batches += 1
        for r in reqs:
            r.started_at = time.perf_counter()

        out = np.zeros((len(reqs), max_new), np.int32)
        done = np.zeros(len(reqs), bool)
        temps_np = np.array([r.temperature for r in reqs], np.float32)
        # all-greedy batches (the default) keep the scalar fast path in
        # sample(), skipping the per-step Gumbel draw over the vocab
        temps = 0.0 if (temps_np <= 0.0).all() else jnp.asarray(temps_np)
        budgets = np.array([r.max_new for r in reqs])
        for step in range(max_new):
            self.key, sk = jax.random.split(self.key)
            cur = sample(sk, logits, temperature=temps,
                         vocab_size=self.cfg.vocab_size)
            out[:, step] = np.asarray(cur[:, 0])
            if step == 0:
                now = time.perf_counter()
                for r in reqs:
                    r.first_token_at = now
            done |= out[:, step] == self.eos_id
            done |= step + 1 >= budgets
            if done.all():
                break
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, cur)
            logits.block_until_ready()
            self.stats.decode_wall_s += time.perf_counter() - t0
            self.stats.decode_tokens += int((~done).sum())
            self.stats.decode_steps += 1
        for i, r in enumerate(reqs):
            row = out[i, : r.max_new]
            end = np.argmax(row == self.eos_id) if (row ==
                                                    self.eos_id).any() \
                else r.max_new
            r.result = row[: max(int(end), 1)]
            r.finished_at = time.perf_counter()
        self.stats.served += len(reqs)
        return reqs

    def logprob_of(self, tokens: np.ndarray) -> float:
        """Sequence log-likelihood under this engine's model — used by the
        real-engine duel judges (DESIGN.md §6.2)."""
        t = jnp.asarray(tokens[None, :])
        logits = registry.apply_logits(self.params, self.cfg,
                                       {"tokens": t[:, :-1]},
                                       q_chunk=256, kv_chunk=256)
        logits = logits.astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        gold = jnp.take_along_axis(lp, t[:, 1:, None], axis=-1)
        return float(jnp.sum(gold))
