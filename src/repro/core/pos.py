"""Proof-of-Stake executor / judge sampling (paper §3.2, §4.2, Assumption 5.3).

Selection probability of node i is s_i / sum_j s_j over the eligible set.
Sampling is without replacement for multi-winner draws (duel executors,
judges), matching "two executors sampled via our PoS-based selection" +
"k judges (also selected via PoS)".
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def selection_probs(stakes: Dict[str, float], eligible: Sequence[str]) -> Dict[str, float]:
    w = {n: max(0.0, stakes.get(n, 0.0)) for n in eligible}
    tot = sum(w.values())
    if tot <= 0.0:
        # degenerate: uniform over eligible (no stake anywhere)
        return {n: 1.0 / len(eligible) for n in eligible} if eligible else {}
    return {n: w[n] / tot for n in w}


def pos_sample(stakes: Dict[str, float], eligible: Sequence[str],
               k: int, rng: np.random.Generator,
               exclude: Sequence[str] = ()) -> List[str]:
    """Draw up to ``k`` distinct nodes, probability proportional to stake."""
    pool = [n for n in eligible if n not in set(exclude)]
    out: List[str] = []
    while pool and len(out) < k:
        probs = selection_probs(stakes, pool)
        names = list(probs)
        p = np.asarray([probs[n] for n in names])
        p = p / p.sum()
        pick = names[int(rng.choice(len(names), p=p))]
        out.append(pick)
        pool.remove(pick)
    return out


def pos_sample_one(stakes: Dict[str, float], eligible: Sequence[str],
                   rng: np.random.Generator,
                   exclude: Sequence[str] = ()) -> Optional[str]:
    got = pos_sample(stakes, eligible, 1, rng, exclude)
    return got[0] if got else None
