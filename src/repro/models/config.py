"""Model configuration schema covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # dense-transformer knobs
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    qk_norm: bool = False           # Qwen3-style per-head RMSNorm on q/k
    use_bias: bool = False
    parallel_block: bool = False    # Cohere Command-R parallel attn+FFN
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    mrope: bool = False             # Qwen2-VL multimodal 3-axis RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # t/h/w split of head_dim/2
    sliding_window: Optional[int] = None   # per-layer window (None = full)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # hybrid (RecurrentGemma / Griffin): block pattern within one scan group
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn")
    rglru_width: int = 0                   # recurrence width (= d_model here)
    conv_width: int = 4
    local_window: int = 2048               # local attention window

    # ssm (xLSTM): mLSTM/sLSTM pattern within one scan group
    xlstm_pattern: Tuple[str, ...] = ()    # e.g. ("m",)*7 + ("s",)
    xlstm_up_factor: float = 2.0

    # KV-cache quantization (dense family; §Perf capacity variant)
    kv_quant: bool = False       # int8 KV with per-token-per-head scales

    # encoder-decoder (Whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500                # 30 s of mel frames after conv stub

    # modality frontend stub (vlm / audio): inputs are embeddings, not tokens
    embeds_input: bool = False

    # end-of-sequence token id: terminates decode in the serving engine and
    # pads prompt batches (the pads are causally/length-masked inert)
    eos_id: int = 1

    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.family == "ssm":
            blk = self._xlstm_block_params()
            return emb + L * blk
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.is_moe:
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
        if self.family == "hybrid":
            # mix of recurrent + attention temporal blocks, each followed by MLP
            n_attn = sum(1 for b in self._hybrid_layers() if b == "attn")
            n_rec = L - n_attn
            rec = 3 * d * d + 2 * d  # gates + projections (approx)
            return emb + n_attn * (attn + mlp) + n_rec * (rec + mlp)
        return emb + L * (attn + mlp)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE uses top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        mlp = self.top_k * 3 * d * f + d * self.n_experts
        return emb + L * (attn + mlp)

    def _hybrid_layers(self) -> Tuple[str, ...]:
        pat = self.block_pattern or ("rec", "rec", "attn")
        out = []
        while len(out) < self.n_layers:
            out.extend(pat)
        return tuple(out[: self.n_layers])

    def _xlstm_block_params(self) -> int:
        d = self.d_model
        return int(8 * d * d * self.xlstm_up_factor)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def draft(self) -> "ModelConfig":
        """Tiny same-tokenizer sibling for speculative drafting (DESIGN.md
        §6.1-spec): shares ``vocab_size``/``eos_id`` (token ids must agree
        between draft and target) but shrinks every capacity knob, so k
        draft forwards cost a fraction of one target forward.  Dense-family
        layout so the draft runs the slot-decode path."""
        return self.replace(
            name=self.name + "-draft",
            family="dense",
            n_layers=2, d_model=128, n_heads=2, n_kv_heads=1,
            d_ff=256, head_dim=64,
            sliding_window=None, mrope=False, embeds_input=False,
            n_experts=0, top_k=0, kv_quant=False,
            qk_norm=False, use_bias=False, parallel_block=False)

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=max(2, len(self.block_pattern) or 2,
                         len(self.xlstm_pattern) or 2),
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=512 if self.d_ff else 0,
            head_dim=64,
            vocab_size=512,
        )
        if self.is_moe:
            kw.update(n_experts=4, top_k=min(self.top_k, 2))
        if self.mrope:
            # sections must sum to head_dim/2 (=32 in smoke variants)
            kw.update(mrope_sections=(8, 12, 12))
        if self.family == "audio":
            kw.update(n_encoder_layers=2, encoder_seq=64)
        if self.family == "hybrid":
            kw.update(n_layers=3, local_window=64,
                      rglru_width=min(self.rglru_width or 256, 256))
        if self.family == "ssm":
            kw.update(n_layers=len(self.xlstm_pattern) or 2)
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.replace(**kw)
