"""Command-R+ 104B [hf:CohereForAI/c4ai-command-r-v01] — dense, parallel block, no bias."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    norm_type="layernorm",
    parallel_block=True,         # Cohere parallel attention + FFN
    use_bias=False,
    rope_theta=7.5e4,
    tie_embeddings=True,
)
