"""RecurrentGemma-9B [arXiv:2402.19427] — hybrid RG-LRU + local attention, 1:2."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,                 # 12 x (rec, rec, attn) + 2-layer rec tail
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                # MQA in the local-attention blocks
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    rglru_width=4096,
    conv_width=4,
    local_window=2048,
    act="gelu",
)
