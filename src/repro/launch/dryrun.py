import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
# The 512 placeholder host devices exist ONLY here — smoke tests and benches
# see the real single CPU device.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from typing import Dict, Optional, Tuple   # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.compat import meshenv                             # noqa: E402
from repro.configs import INPUT_SHAPES, get_config, grid     # noqa: E402
from repro.launch import sharding as sh                      # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                               make_production_mesh)         # noqa: E402
from repro.launch.specs import input_specs                   # noqa: E402
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)            # noqa: E402
from repro.models import runtime                             # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (post-SPMD module)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)", line)
        if not m:
            continue
        result_shape, op = m.groups()
        op = op.rstrip(".0123456789")
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        out[base] += _shape_bytes(result_shape)
    return out


def hbm_traffic_bytes(hlo_text: str) -> float:
    """As-if-fused HBM traffic estimate from the optimized HLO graph.

    XLA:CPU fuses far less than XLA:TPU, so raw ``bytes accessed`` counts
    every elementwise instruction's operands as HBM traffic.  We instead walk
    the instruction graph and count operand + result bytes only for ops that
    are HBM-traffic boundaries on TPU (dots, reduces, collectives, gathers/
    scatters, slices, fusions), treating elementwise/broadcast/reshape chains
    as fused.  See EXPERIMENTS.md §Roofline for the definition.
    """
    heavy_prefixes = ("dot", "convolution", "fusion", "reduce",
                      "all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute", "gather", "scatter",
                      "dynamic-slice", "dynamic-update-slice", "sort", "copy",
                      "transpose", "custom-call")
    line_re = re.compile(
        r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)"
        r"\(([^)]*)\)")
    sizes: Dict[str, int] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = line_re.match(line)
        if not m:
            continue
        name, shape_txt, op, operands = m.groups()
        nbytes = _shape_bytes(shape_txt)
        sizes[name] = nbytes
        opb = op.rstrip(".0123456789")
        if any(opb == p or opb.startswith(p) for p in heavy_prefixes):
            opnd = 0
            for tok in operands.split(","):
                tok = tok.strip().lstrip("%").split(" ")[0]
                opnd += sizes.get(tok, 0)
            total += nbytes + opnd
    return total


def _lower(arch: str, shape_name: str, mesh, kw: Dict, *,
           roofline: bool = False, k_groups: Optional[int] = None):
    """One lowering; roofline=True unrolls structural loops for exact counts;
    k_groups lowers a reduced-depth config (roofline extrapolation)."""
    kw = dict(kw)
    flags = {k: kw.pop(k) for k in ("seq_parallel_", "decode_seq_shard_",
                                    "attn_batch_only_", "gqa_native_",
                                    "moe_a2a_")
             if k in kw}
    data_fsdp = not kw.pop("tp_only_params", False)
    donate_cache = kw.pop("donate_cache", False)
    pad_heads = kw.pop("pad_heads", None)
    kv_quant = kw.pop("kv_quant", False)
    base_cfg = get_config(arch, shape_name)
    if pad_heads:
        base_cfg = base_cfg.replace(n_heads=pad_heads)
    if kv_quant:
        base_cfg = base_cfg.replace(kv_quant=True)
    cfg_override = base_cfg if (pad_heads or kv_quant or k_groups is None) else None
    if k_groups is not None:
        from repro.launch.specs import reduced_depth
        cfg_override = reduced_depth(base_cfg, k_groups)
    specs = input_specs(arch, shape_name, cfg_override=cfg_override)
    cfg, shp = specs["cfg"], specs["shape"]
    if roofline:
        kw["microbatches"] = 1
    ctx = runtime.roofline_lowering() if roofline else _nullctx()
    with runtime.perf_flags(**flags), ctx, meshenv.mesh_context(mesh):
        if shp.kind == "train":
            step = build_train_step(cfg, shp, **kw)
            pshard = sh.params_shardings(specs["state"]["params"], mesh,
                                         data_fsdp=data_fsdp)
            oshard = {"mu": pshard, "nu": pshard,
                      "step": NamedSharding(mesh, P())}
            state_sh = {"params": pshard, "opt": oshard}
            batch_sh = sh.batch_shardings(specs["batch"], mesh)
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None)
                              ).lower(specs["state"], specs["batch"])
        elif shp.kind == "prefill":
            step = build_prefill_step(cfg, shp,
                                      **{k: v for k, v in kw.items()
                                         if k.endswith("chunk")})
            pshard = sh.params_shardings(specs["params"], mesh,
                                         data_fsdp=data_fsdp)
            batch_sh = sh.batch_shardings(specs["batch"], mesh)
            cache_struct = jax.eval_shape(step, specs["params"],
                                          specs["batch"])[1]
            cache_sh = sh.cache_shardings(cache_struct, mesh)
            lowered = jax.jit(step, in_shardings=(pshard, batch_sh),
                              out_shardings=(None, cache_sh)
                              ).lower(specs["params"], specs["batch"])
        else:
            step = build_serve_step(cfg, shp)
            pshard = sh.params_shardings(specs["params"], mesh,
                                         data_fsdp=data_fsdp)
            cache_sh = sh.cache_shardings(specs["cache"], mesh)
            tok_sh = sh.batch_shardings(specs["token"], mesh)
            lowered = jax.jit(step, in_shardings=(pshard, cache_sh, tok_sh),
                              out_shardings=(None, cache_sh),
                              donate_argnums=(1,) if donate_cache else ()
                              ).lower(specs["params"], specs["cache"],
                                      specs["token"])
        compiled = lowered.compile()
    return compiled, cfg, shp


import contextlib                                            # noqa: E402


def _nullctx():
    return contextlib.nullcontext()


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              perf_variant: Optional[str] = None,
              with_roofline: Optional[bool] = None):
    """Lower + compile one (arch × shape) on the production mesh.

    Two lowerings: FIT (production scan structure -> memory analysis and the
    compile-success proof; the only one run for multi-pod) and ROOFLINE
    (loops unrolled -> exact flops/bytes/collective counts; single-pod only).
    perf_variant enables §Perf hillclimb configs (see EXPERIMENTS.md).
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(mesh.devices.size)
    kw: Dict = {}
    for part in (perf_variant or "").split("+"):
        if part == "skip_blocks":
            kw["skip_masked_blocks"] = True
        elif part == "seqpar":
            kw["seq_parallel_"] = True
        elif part == "lsedecode":
            kw["decode_seq_shard_"] = True
        elif part == "attnbatch":
            kw["attn_batch_only_"] = True
        elif part == "tponly":
            kw["tp_only_params"] = True
        elif part == "gqanative":
            kw["gqa_native_"] = True
        elif part == "donate":
            kw["donate_cache"] = True
        elif part == "kvint8":
            kw["kv_quant"] = True
        elif part == "moea2a":
            kw["moe_a2a_"] = True
        elif part.startswith("padheads"):
            kw["pad_heads"] = int(part[len("padheads"):])
        elif part.startswith("qchunk"):
            kw["q_chunk"] = kw["kv_chunk"] = int(part[len("qchunk"):])
        elif part.startswith("mb"):
            kw["microbatches"] = int(part[2:])

    t0 = time.time()
    compiled, cfg, shp = _lower(arch, shape_name, mesh, kw)
    t_fit = time.time() - t0
    mem = compiled.memory_analysis()
    report = {
        "arch": arch, "shape": shape_name, "kind": shp.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "perf_variant": perf_variant or "baseline",
        "compile_s": round(t_fit, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
    }

    if with_roofline is None:
        with_roofline = not multi_pod
    if not with_roofline:
        return compiled, report

    # Roofline terms by exact linear extrapolation over the homogeneous layer
    # stack: lower 1-group and 2-group reduced configs with loops unrolled;
    # per-group delta x (G-1) + 1-group base gives the full-depth counts.
    from repro.launch.specs import n_groups_of

    def stats(k_groups: int):
        rc, rcfg, _ = _lower(arch, shape_name, mesh, kw, roofline=True,
                             k_groups=k_groups)
        cost = rc.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # older JAX: one dict per device
            cost = cost[0] if cost else {}
        hlo = rc.as_text()
        return {"flops": float(cost.get("flops", 0.0)),
                "hbm": hbm_traffic_bytes(hlo),
                "coll": collective_bytes(hlo)}

    t0 = time.time()
    s1 = stats(1)
    s2 = stats(2)
    t_roof = time.time() - t0
    G = n_groups_of(get_config(arch, shape_name))

    def extrap(a, b):
        return a + (G - 1) * (b - a)

    flops = max(extrap(s1["flops"], s2["flops"]), 0.0)
    bytes_acc = max(extrap(s1["hbm"], s2["hbm"]), 0.0)
    coll = {k: max(extrap(s1["coll"][k], s2["coll"][k]), 0.0)
            for k in s1["coll"]}
    coll_total = sum(coll.values())

    compute_t = flops / PEAK_FLOPS_BF16
    memory_t = bytes_acc / HBM_BW
    collective_t = coll_total / ICI_BW_PER_LINK
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: useful-math floor for this step
    n_active = cfg.n_active_params()
    tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode" else 1)
    model_flops = (6.0 if shp.kind == "train" else 2.0) * n_active * tokens
    hlo_flops_global = flops * n_chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    report["roofline_compile_s"] = round(t_roof, 1)
    report["per_device"] = {"flops": flops, "bytes_accessed": bytes_acc}
    report["collective_bytes"] = coll
    report["roofline"] = {
        "compute_ms": round(compute_t * 1e3, 4),
        "memory_ms": round(memory_t * 1e3, 4),
        "collective_ms": round(collective_t * 1e3, 4),
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_fraction": round(useful, 4),
    }
    return compiled, report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--perf-variant", default=None)
    ap.add_argument("--out", default=None, help="append JSONL reports here")
    args = ap.parse_args(argv)

    combos = grid() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape in combos:
        try:
            _, rep = lower_one(arch, shape, multi_pod=args.multi_pod,
                               perf_variant=args.perf_variant)
            line = json.dumps(rep)
            print(line, flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(line + "\n")
        except Exception as e:  # noqa: BLE001 — report and continue
            failures.append((arch, shape, repr(e)))
            print(json.dumps({"arch": arch, "shape": shape,
                              "error": repr(e)[:500]}), flush=True)
    if failures:
        print(f"FAILED {len(failures)}/{len(combos)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
