"""Substrate kernels: Pallas (interpret mode) vs jnp oracle — allclose + µs."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.flash_decode import flash_decode_tpu
from repro.models.attention import (decode_attention, flash_attention,
                                    reference_attention)


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw)[0].block_until_ready() if isinstance(fn(*args, **kw),
                                                         tuple) else \
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def main(rows: List[str]) -> None:
    key = jax.random.PRNGKey(0)
    # prefill kernel
    b, s, h, hkv, d = 2, 512, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    ref = reference_attention(q, k, v, causal=True)
    out = flash_attention_tpu(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    us_pallas = _time(lambda: flash_attention_tpu(
        q, k, v, causal=True, block_q=128, block_k=128, interpret=True))
    us_ref = _time(lambda: flash_attention(q, k, v, causal=True,
                                           q_chunk=128, kv_chunk=128))
    rows.append(f"kernel_flash_prefill,{us_pallas:.0f},"
                f"max_err={err:.2e};jnp_oracle_us={us_ref:.0f};"
                f"allclose={err < 2e-5}")

    # decode kernel
    b, s, h, hkv, d = 4, 2048, 8, 2, 64
    ks = jax.random.split(key, 3)
    q1 = jax.random.normal(ks[0], (b, 1, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    cl = jnp.asarray(1536, jnp.int32)
    refd = decode_attention(q1, kc, vc, cl)
    outd = flash_decode_tpu(q1, kc, vc, cl, block_k=512, interpret=True)
    errd = float(jnp.max(jnp.abs(outd - refd)))
    us_pallas = _time(lambda: flash_decode_tpu(q1, kc, vc, cl, block_k=512,
                                               interpret=True))
    us_ref = _time(lambda: decode_attention(q1, kc, vc, cl))
    rows.append(f"kernel_flash_decode,{us_pallas:.0f},"
                f"max_err={errd:.2e};jnp_oracle_us={us_ref:.0f};"
                f"allclose={errd < 2e-5}")


if __name__ == "__main__":
    rows: List[str] = []
    main(rows)
    print("\n".join(rows))
