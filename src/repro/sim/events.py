"""Deterministic discrete-event loop.

The WWW.Serve experiments (paper Figs 4-8) ran on real GPUs over 750s of wall
clock.  We reproduce them with a seeded discrete-event simulator: protocol
logic (routing, gossip, ledger, duels) executes the *real* implementation;
only backend generation time is modeled (see ``sim.servicemodel``).
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)


class EventLoop:
    """Minimal heapq-based event loop with deterministic tie-breaking."""

    def __init__(self) -> None:
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self._stopped = False

    def schedule(self, delay: float, fn: Callable[[], None]) -> _Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = _Event(self.now + delay, next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def schedule_at(self, time: float, fn: Callable[[], None]) -> _Event:
        return self.schedule(max(0.0, time - self.now), fn)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def stop(self) -> None:
        self._stopped = True

    def run(self, until: Optional[float] = None) -> float:
        """Run until the heap drains or ``until`` (sim seconds) is reached."""
        self._stopped = False
        while self._heap and not self._stopped:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if until is not None and ev.time > until:
                # put it back; caller may resume later
                heapq.heappush(self._heap, ev)
                self.now = until
                break
            self.now = ev.time
            ev.fn()
        else:
            if until is not None and self.now < until:
                self.now = until
        return self.now

    def pending(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)
