"""User-level policy framework (paper §4.3, §7.2).

Each provider independently configures when it offloads its own queue, when it
accepts delegated work, how much it stakes, and whether its own users get
priority.  System-level policies (PoS routing, ledger, gossip, duel-and-judge)
are implemented in their respective modules and are not provider-tunable.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class NodePolicy:
    """Paper defaults (Appendix C): offload 80%, accept 80%, target util 70%."""

    stake: float = 10.0              # initial stake amount
    offload_freq: float = 0.8        # prob. of offloading an eligible request
    accept_freq: float = 0.8         # prob. of accepting a delegated request
    target_utilization: float = 0.7  # accept delegated work only below this
    offload_queue_threshold: int = 4 # offload if local queue exceeds this ...
    offload_util_threshold: float = 1.2  # ... or utilization passes the knee
    prioritize_local: bool = True    # own users served before delegated work
    max_delegated_queue: int = 64    # hard cap on queued delegated requests
    offload_price: float = 1.0       # credits paid per delegated request

    def wants_offload(self, queue_len: int, n_active: int, saturation: int,
                      balance: float, rng: np.random.Generator) -> bool:
        """Should this node try to delegate one of its queued requests?"""
        overloaded = (queue_len > self.offload_queue_threshold
                      or n_active / max(1, saturation) >= self.offload_util_threshold)
        can_pay = balance >= self.offload_price
        return overloaded and can_pay and rng.random() < self.offload_freq

    def accepts_delegated(self, n_active: int, saturation: int,
                          delegated_queue: int, rng: np.random.Generator) -> bool:
        """Probe response: is this node willing to take remote work now?"""
        util = n_active / max(1, saturation)
        if util >= self.target_utilization:
            return False
        if delegated_queue >= self.max_delegated_queue:
            return False
        return rng.random() < self.accept_freq
