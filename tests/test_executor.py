"""Executor layer: analytic parity, burst dynamics, KV admission, churn
drain, and slot-based continuous batching on the real engine (DESIGN.md §6.1).
"""

import numpy as np
import pytest

from repro.core import Network, Node, NodePolicy
from repro.core.node import QueuedRequest
from repro.sim import (BackendProfile, EventLoop, TokenBucketExecutor,
                       make_profile)
from repro.sim.workload import Request


def _qr(rid, prompt, output, t=0.0):
    return QueuedRequest(
        Request(rid=rid, origin="n", arrival=t, prompt_tokens=prompt,
                output_tokens=output, slo_s=600.0),
        enqueue_time=t, delegated=False, origin_node="n")


class _Harness:
    """A TokenBucketExecutor on a bare event loop, recording completions."""

    def __init__(self, profile):
        self.loop = EventLoop()
        self.ex = TokenBucketExecutor(profile)
        self.done = {}
        self.ex.bind(self.loop, self._cb)

    def _cb(self, qr, started_at, first_token_at):
        self.done[qr.req.rid] = dict(finish=self.loop.now,
                                     started=started_at,
                                     first_token=first_token_at)


class TestTokenBucketParity:
    """At steady state the executor reduces to the analytic service_time."""

    def test_single_request_matches_analytic(self):
        prof = make_profile()            # qwen3-8b on A100
        h = _Harness(prof)
        assert h.ex.admit(_qr("a", 512, 2048))
        h.loop.run()
        expected = prof.service_time(512, 2048, 1)
        assert h.done["a"]["finish"] == pytest.approx(expected, rel=1e-6)
        assert h.done["a"]["first_token"] == pytest.approx(
            512 / prof.prefill_tps, rel=1e-6)

    def test_saturated_uniform_batch_matches_analytic(self):
        """k identical streams hold a constant batch until they all finish
        together, so each must see exactly service_time(p, o, k)."""
        prof = make_profile()
        k = 2 * prof.saturation          # past the knee: share = 2
        h = _Harness(prof)
        for i in range(k):
            assert h.ex.admit(_qr(f"r{i}", 256, 1024))
        h.loop.run()
        expected = prof.service_time(256, 1024, k)
        assert len(h.done) == k
        for rec in h.done.values():
            assert rec["finish"] == pytest.approx(expected, rel=1e-6)

    def test_subsaturated_batch_is_unshared(self):
        prof = make_profile()
        h = _Harness(prof)
        for i in range(prof.saturation // 2):
            assert h.ex.admit(_qr(f"r{i}", 256, 1024))
        h.loop.run()
        expected = prof.service_time(256, 1024, 1)   # below knee: full speed
        for rec in h.done.values():
            assert rec["finish"] == pytest.approx(expected, rel=1e-6)


class TestTokenBucketDynamics:
    PROF = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                          max_concurrency=8, quality=0.5,
                          kv_token_budget=10**9)

    def test_burst_slows_inflight_request(self):
        """A burst landing mid-decode must slow the request that is already
        running — the exact behavior frozen-share scheduling cannot model."""
        prof = self.PROF
        h = _Harness(prof)
        assert h.ex.admit(_qr("a", 100, 1000))
        t_burst = 5.0
        h.loop.run(until=t_burst)
        for i in range(3):
            assert h.ex.admit(_qr(f"b{i}", 100, 1000, t=t_burst))
        h.loop.run()
        solo = prof.service_time(100, 1000, 1)
        # integrate by hand: full speed until the burst, half speed after
        ttft = 100 / prof.prefill_tps
        decoded = (t_burst - ttft) * prof.decode_tps
        expected = t_burst + (1000 - decoded) / (prof.decode_tps / 2.0)
        assert h.done["a"]["finish"] > solo * 1.2
        assert h.done["a"]["finish"] == pytest.approx(expected, rel=1e-6)

    def test_drain_speeds_up_survivors(self):
        """Short streams leaving the batch must speed the long one back up
        (share recomputed on every membership change)."""
        prof = self.PROF
        h = _Harness(prof)
        assert h.ex.admit(_qr("long", 100, 2000))
        for i in range(3):
            assert h.ex.admit(_qr(f"s{i}", 100, 100))
        h.loop.run()
        # shared at 4 streams only while the short ones live; afterwards the
        # long stream runs unshared, so it beats the frozen-share-of-4 time
        frozen = prof.service_time(100, 2000, 4)
        assert h.done["long"]["finish"] < frozen * 0.75

    def test_kv_token_budget_gates_admission(self):
        prof = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=1000)
        h = _Harness(prof)
        assert h.ex.admit(_qr("a", 100, 400))          # kv 500
        assert h.ex.admit(_qr("b", 100, 300))          # kv 400 -> used 900
        assert not h.ex.admit(_qr("c", 100, 200))      # kv 300 > headroom
        h.loop.run()                                   # b frees 400
        assert h.ex.admit(_qr("c", 100, 200))
        ld = h.ex.load()
        assert ld.kv_used == 300 and ld.kv_budget == 1000
        assert 0.0 < ld.kv_headroom < 1.0

    def test_oversized_request_admitted_when_empty(self):
        prof = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=1000)
        h = _Harness(prof)
        assert h.ex.admit(_qr("huge", 4000, 4000))     # kv 8000 > budget
        h.loop.run()
        assert "huge" in h.done

    def test_load_snapshot_tracks_progress(self):
        prof = self.PROF
        h = _Harness(prof)
        assert h.ex.admit(_qr("a", 1000, 1000))
        ld0 = h.ex.load()
        assert ld0.active_streams == 1
        assert ld0.pending_prefill_tokens == 1000
        h.loop.run(until=0.05)                         # prefill half done
        ld1 = h.ex.load()
        assert ld1.pending_prefill_tokens < ld0.pending_prefill_tokens
        h.loop.run(until=5.0)                          # mid-decode
        ld2 = h.ex.load()
        assert ld2.pending_prefill_tokens == 0
        assert 0 < ld2.pending_decode_tokens < 1000


class TestNodeExecutorIntegration:
    def _net(self, mode="single"):
        net = Network(mode=mode, seed=0, init_balance=100.0)
        prof = BackendProfile(prefill_tps=1e4, decode_tps=50.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=4000)
        net.add_node(Node("n1", prof, policy=NodePolicy()))
        net.add_node(Node("n2", make_profile(), policy=NodePolicy()))
        return net

    def test_queued_requests_wait_for_kv_headroom(self):
        net = self._net()
        reqs = [Request(rid=f"r{i}", origin="n1", arrival=0.0,
                        prompt_tokens=500, output_tokens=1000, slo_s=600.0)
                for i in range(6)]                     # kv 1500 each
        m = net.run(reqs, until=500.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == 6
        # only 2 fit the 4000-token budget at once: later requests must have
        # waited in the queue (positive queue_wait), earlier ones not
        waits = sorted(c.queue_wait for c in user)
        assert waits[0] == pytest.approx(0.0, abs=1e-9)
        assert waits[-1] > 1.0
        assert all(np.isfinite(c.ttft) and c.ttft >= 0 for c in user)

    def test_go_offline_drains_queue_to_peers(self):
        """Churn bugfix: queued (not yet admitted) requests must be handed
        back to the network instead of stranding until a rejoin."""
        net = self._net()
        reqs = [Request(rid=f"r{i}", origin="n1", arrival=0.1 * i,
                        prompt_tokens=500, output_tokens=1000, slo_s=600.0)
                for i in range(10)]
        net.loop.schedule(5.0, lambda: net.nodes["n1"].go_offline())
        m = net.run(reqs, until=500.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == 10                         # nothing stranded
        assert net.nodes["n1"].queue_len == 0
        # n2 picked up the drained queue even though n1 never rejoined
        assert any(c.executor == "n2" for c in user)

    def test_delivery_racing_churn_bounces_to_network(self):
        """A delegated delivery already in flight when its target goes
        offline must bounce back to the network, not re-strand."""
        net = self._net()
        req = Request(rid="late", origin="n2", arrival=0.0,
                      prompt_tokens=100, output_tokens=100, slo_s=600.0)
        net.loop.schedule(1.0, lambda: net.nodes["n1"].go_offline())
        net.loop.schedule(1.5, lambda: net.nodes["n1"].enqueue(
            QueuedRequest(req, 1.5, delegated=True, origin_node="n2")))
        m = net.run([], until=50.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == 1 and user[0].executor == "n2"


class TestEngineSlotBatching:
    @pytest.fixture(scope="class")
    def setup(self):
        import jax
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        params = registry.init(jax.random.PRNGKey(0), cfg)
        return cfg, params

    def _reqs(self):
        from repro.serving import GenRequest
        prompts = [np.random.default_rng(i).integers(2, 400, size=10 + 2 * i)
                   .astype(np.int32) for i in range(3)]
        budgets = [4, 24, 4]
        return [GenRequest(rid=f"r{i}", tokens=prompts[i],
                           max_new=budgets[i]) for i in range(3)]

    def test_slot_matches_wave_greedy_in_fewer_steps(self, setup):
        """Mixed output budgets: identical greedy outputs, strictly fewer
        decode steps — a short request no longer rides out the longest
        request's budget, and a queued one starts in its freed slot."""
        from repro.serving import Engine
        cfg, params = setup
        slot = Engine(cfg, params, max_batch=2, bucket=16, continuous=True)
        wave = Engine(cfg, params, max_batch=2, bucket=16, continuous=False)
        rs = slot.serve(self._reqs())
        rw = wave.serve(self._reqs())
        for a, b in zip(rs, rw):
            np.testing.assert_array_equal(a.result, b.result)
        assert slot.stats.served == wave.stats.served == 3
        assert slot.stats.decode_steps < wave.stats.decode_steps

    def test_engine_executor_contract(self, setup):
        from repro.serving import Engine, EngineExecutor
        cfg, params = setup
        ex = EngineExecutor(Engine(cfg, params, max_batch=2, bucket=16))
        completions = []
        ex.bind(None, lambda r, st, ft: completions.append((r, st, ft)))
        for r in self._reqs():
            assert ex.admit(r)
        ld = ex.load()
        assert ld.queued_streams == 3 and ld.active_streams == 0
        ex.step()                                      # admits + first tokens
        ld = ex.load()
        assert ld.active_streams > 0
        assert ld.kv_used > 0 and 0.0 <= ld.kv_headroom < 1.0
        done = ex.drain()
        assert len(completions) == 3 and len(done) == 3
        for r, started, first_tok in completions:
            assert r.result is not None and len(r.result) >= 1
            assert first_tok >= started > 0
        assert np.isfinite(ex.estimate(16, 8))
