"""Jit'd dispatch wrappers: Pallas kernel on TPU, jnp oracle elsewhere.

The model zoo calls these entry points; ``backend="auto"`` picks the Pallas
kernel when running on real TPU hardware and the jnp reference otherwise
(this container is CPU-only, so 'auto' = reference; kernels are still
exercised in interpret mode by the test suite and benchmarks).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.flash_decode import flash_decode_tpu
from repro.kernels.paged_decode import flash_paged_decode_tpu
from repro.kernels.ref import (decode_ref, flash_ref, paged_decode_quant_ref,
                               paged_decode_ref, paged_verify_quant_ref,
                               paged_verify_ref)
from repro.kernels.spec_verify import flash_paged_verify_tpu


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "backend",
                                             "interpret"))
def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              backend: str = "auto", interpret: bool = True) -> jax.Array:
    """Prefill/train attention. q: (B,Sq,H,D); k/v: (B,Skv,Hkv,D)."""
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                   interpret=interpret and not _on_tpu())
    return flash_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("window", "backend", "interpret"))
def decode(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None,
           backend: str = "auto", interpret: bool = True) -> jax.Array:
    """Single-token decode. q: (B,1,H,D); caches: (B,S,Hkv,D)."""
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        return flash_decode_tpu(q, k_cache, v_cache, cache_len, window=window,
                                interpret=interpret and not _on_tpu())
    return decode_ref(q, k_cache, v_cache, cache_len, window=window)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "pages_per_step"))
def paged_decode(q, k_pool, v_pool, block_tables, lengths, *,
                 backend: str = "auto", interpret: bool = True,
                 pages_per_step: Optional[int] = None) -> jax.Array:
    """Block-table paged decode. q: (B,1,H,D); pools: (P,page,Hkv,D);
    block_tables: (B,maxp) int32; lengths: (B,) int32.  ``pages_per_step``
    overrides the recorded kernel tuning (Pallas path only)."""
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        return flash_paged_decode_tpu(q, k_pool, v_pool, block_tables,
                                      lengths,
                                      pages_per_step=pages_per_step,
                                      interpret=interpret and not _on_tpu())
    return paged_decode_ref(q, k_pool, v_pool, block_tables, lengths)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "pages_per_step"))
def paged_decode_quant(q, k_pool, v_pool, k_scale, v_scale, block_tables,
                       lengths, *, backend: str = "auto",
                       interpret: bool = True,
                       pages_per_step: Optional[int] = None) -> jax.Array:
    """Int8 block-table paged decode (DESIGN.md §6.1-paged): int8 pools
    plus (P,page,Hkv,1) per-token-per-head scale pools riding the same
    block-table indirection; dequantized in the kernel body."""
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        return flash_paged_decode_tpu(q, k_pool, v_pool, block_tables,
                                      lengths, k_scale=k_scale,
                                      v_scale=v_scale,
                                      pages_per_step=pages_per_step,
                                      interpret=interpret and not _on_tpu())
    return paged_decode_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                  block_tables, lengths)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "pages_per_step"))
def paged_verify(q, k_pool, v_pool, block_tables, lengths, *,
                 backend: str = "auto", interpret: bool = True,
                 pages_per_step: Optional[int] = None) -> jax.Array:
    """Multi-token speculative verify over paged KV (DESIGN.md §6.1-spec).
    q: (B,K,H,D) — K new tokens whose KV is already in the pool; pools:
    (P,page,Hkv,D); block_tables: (B,maxp) int32; lengths: (B,) int32
    valid tokens per row before the K new tokens."""
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        return flash_paged_verify_tpu(q, k_pool, v_pool, block_tables,
                                      lengths,
                                      pages_per_step=pages_per_step,
                                      interpret=interpret and not _on_tpu())
    return paged_verify_ref(q, k_pool, v_pool, block_tables, lengths)


@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "pages_per_step"))
def paged_verify_quant(q, k_pool, v_pool, k_scale, v_scale, block_tables,
                       lengths, *, backend: str = "auto",
                       interpret: bool = True,
                       pages_per_step: Optional[int] = None) -> jax.Array:
    """Int8 multi-token speculative verify over paged KV: int8 pools plus
    scale pools, dequantized in the kernel body (DESIGN.md §6.1-spec)."""
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        return flash_paged_verify_tpu(q, k_pool, v_pool, block_tables,
                                      lengths, k_scale=k_scale,
                                      v_scale=v_scale,
                                      pages_per_step=pages_per_step,
                                      interpret=interpret and not _on_tpu())
    return paged_verify_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                                  block_tables, lengths)
