"""Training launcher: runs a real (host-scale) training loop.

Production pods use the same ``build_train_step`` the dry-run lowers; on this
CPU container you train reduced ("smoke") variants, e.g.::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --steps 50 --batch 8 --seq 128 --smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.training import AdamWConfig, init_state, make_train_step
from repro.training import checkpoint as ckpt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced same-family variant (CPU)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke().replace(dtype="float32")
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"family={cfg.family}")
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps)
    state = init_state(jax.random.PRNGKey(args.seed), cfg)
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=1,
                                      q_chunk=64, kv_chunk=64))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch, seed=args.seed))
    t0 = time.time()
    for i in range(args.steps):
        raw = pipe.batch(i)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.family == "audio":
            batch["encoder_embeds"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.embeds_input:
            tokens = batch.pop("tokens")
            batch["embeds"] = jax.nn.one_hot(
                tokens % cfg.d_model, cfg.d_model, dtype=jnp.float32)
        state, m = step_fn(state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f}")
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s")
    if args.checkpoint:
        ckpt.save(args.checkpoint, state, step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
