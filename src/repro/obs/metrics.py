"""Labeled counters/gauges/histograms (DESIGN.md §Observability).

The unified sink the repo's ad-hoc accumulators feed through: routing
message counts and drop/give-up events from ``core.network``, preemption
and prefix-cache counters from the engines.  Series are identified by a
metric name plus a sorted label set (``counter("net.msg", kind="probe")``),
so one metric fans out into per-kind/per-node series without string
mangling at the call sites.  ``snapshot()`` renders everything as a
JSON-able dict for bench payloads and test assertions.

Instruments are deliberately minimal — a counter is one float and an
``inc`` — because they sit on the simulator's hot paths (every routed
message); anything cleverer (rates, windows) belongs in the consumer.
"""

from __future__ import annotations

from bisect import bisect_left
from typing import Any, Dict, Tuple

# histogram defaults sized for request latencies in seconds
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins level (queue depths, headroom)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Cumulative-bucket histogram with count and sum.

    ``bounds`` are upper bucket edges; observations above the last bound
    land in the implicit +inf bucket (tracked by ``count`` alone).
    """

    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_BUCKETS) -> None:
        self.bounds = tuple(bounds)
        self.counts = [0] * len(self.bounds)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        i = bisect_left(self.bounds, v)
        if i < len(self.counts):
            self.counts[i] += 1
        self.count += 1
        self.sum += v


def _series_key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """A namespace of labeled series, lazily created on first touch.

    Re-requesting a series with the same name+labels returns the same
    instrument (so call sites may cache it or not); requesting an
    existing series as a different instrument type is a bug and raises.
    """

    def __init__(self) -> None:
        self._series: Dict[str, Any] = {}

    def _get(self, cls: type, name: str, labels: Dict[str, Any],
             *args: Any) -> Any:
        key = _series_key(name, labels)
        inst = self._series.get(key)
        if inst is None:
            inst = self._series[key] = cls(*args)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"series {key!r} already registered as "
                f"{type(inst).__name__}, requested as {cls.__name__}")
        return inst

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels: Any) -> Histogram:
        return self._get(Histogram, name, labels, buckets)

    def snapshot(self) -> Dict[str, Any]:
        """Everything recorded so far as a JSON-able dict, keyed by the
        rendered series name (``name{label=value,...}``)."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        for key in sorted(self._series):
            inst = self._series[key]
            if isinstance(inst, Counter):
                out["counters"][key] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][key] = inst.value
            else:
                out["histograms"][key] = {
                    "count": inst.count, "sum": inst.sum,
                    "bounds": list(inst.bounds),
                    "counts": list(inst.counts)}
        return out

    def value(self, name: str, **labels: Any) -> float:
        """Current value of a counter/gauge series (0.0 if never touched)
        — the test-friendly read path."""
        inst = self._series.get(_series_key(name, labels))
        return inst.value if inst is not None else 0.0

    def clear(self) -> None:
        self._series.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry; instrumented objects resolve it
    at construction when not handed an explicit one."""
    return _REGISTRY


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Install ``reg`` as the process-wide default; returns the old one."""
    global _REGISTRY
    old, _REGISTRY = _REGISTRY, reg
    return old
