"""Churn demo (paper Fig 5): nodes joining and leaving mid-flight.

    PYTHONPATH=src python examples/dynamic_participation.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from benchmarks.dynamic import run_join, run_leave


def spark(trace, width: int = 60) -> str:
    blocks = " ▁▂▃▄▅▆▇█"
    vals = [v for _, v in trace]
    lo, hi = min(vals), max(vals)
    return "".join(blocks[int((v - lo) / max(hi - lo, 1e-9) * 8)]
                   for _, v in trace)


def main() -> None:
    j = run_join()
    print("nodes JOIN at", j["events"])
    print("windowed latency:", spark(j["trace"]))
    print(f"SLO attainment: {j['slo']:.3f}\n")
    l = run_leave()
    print("nodes LEAVE at", l["events"])
    print("windowed latency:", spark(l["trace"]))
    print(f"SLO attainment: {l['slo']:.3f}")
    print("\nGossip detects churn; PoS routing adapts — no coordinator.")


if __name__ == "__main__":
    main()
