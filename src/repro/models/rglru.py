"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern (paper arXiv:2402.19427): repeating (recurrent, recurrent,
local-attention); every temporal block is followed by a gated-GeLU MLP.
38 layers = 12 full groups + a 2-layer recurrent tail.

The RG-LRU is a gated linear recurrence
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t),
    a_t = exp(-c · softplus(Λ) · r_t),  r_t, i_t input-dependent sigmoids,
computed with ``jax.lax.associative_scan`` for train/prefill (TPU-friendly
parallel scan — our hardware adaptation of the paper's CUDA linear-scan
kernel) and as a single-step update at decode.  Decode state is O(1) in
sequence length, so `long_500k` runs natively (no KV cache growth).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import runtime
from repro.models import dense
from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ModelConfig

C_RGLRU = 8.0


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def group_structure(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    n_groups = cfg.n_layers // len(pat)
    tail = pat[: cfg.n_layers - n_groups * len(pat)]
    return n_groups, tail


# ------------------------------------------------------------------ params
def _rec_params(key, cfg: ModelConfig, dt) -> Dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 8)
    return {
        "ln": cm.norm_params(d, cfg.norm_type, dt),
        "w_y": cm.dense_init(ks[0], d, w, dt),         # gelu branch
        "w_x": cm.dense_init(ks[1], d, w, dt),         # recurrent branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1
                   ).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": cm.dense_init(ks[3], w, w, dt, scale=0.5),
        "w_i": cm.dense_init(ks[4], w, w, dt, scale=0.5),
        "lam": jnp.asarray(jax.random.uniform(ks[5], (w,), jnp.float32,
                                              0.5, 2.0)),
        "w_o": cm.dense_init(ks[6], w, d, dt),
    }


def _attn_params(key, cfg: ModelConfig, dt) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "ln": cm.norm_params(d, cfg.norm_type, dt),
        "wq": cm.dense_init(ks[0], d, cfg.q_dim, dt),
        "wk": cm.dense_init(ks[1], d, cfg.kv_dim, dt),
        "wv": cm.dense_init(ks[2], d, cfg.kv_dim, dt),
        "wo": cm.dense_init(ks[3], cfg.q_dim, d, dt),
    }


def _mlp_params(key, cfg: ModelConfig, dt) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": cm.norm_params(d, cfg.norm_type, dt),
        "w_gate": cm.dense_init(ks[0], d, f, dt),
        "w_up": cm.dense_init(ks[1], d, f, dt),
        "w_down": cm.dense_init(ks[2], f, d, dt),
    }


def _stack(fn, key, n: int):
    ks = jax.random.split(key, n)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn(k) for k in ks])


def init(key: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dt(cfg)
    n_groups, tail = group_structure(cfg)
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    keys = jax.random.split(key, 8)
    p: Dict = {
        "embed": cm.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": cm.norm_params(cfg.d_model, cfg.norm_type, dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.dense_init(keys[5], cfg.d_model, cfg.padded_vocab, dt)
    group: Dict = {}
    for i, kind in enumerate(pat):
        sub = jax.random.fold_in(keys[1], i)
        mk = (functools.partial(_rec_params, cfg=cfg, dt=dt) if kind == "rec"
              else functools.partial(_attn_params, cfg=cfg, dt=dt))
        group[f"blk{i}"] = _stack(mk, sub, n_groups)
        group[f"mlp{i}"] = _stack(
            functools.partial(_mlp_params, cfg=cfg, dt=dt),
            jax.random.fold_in(keys[2], i), n_groups)
    p["groups"] = group
    tail_p: Dict = {}
    for i, kind in enumerate(tail):
        sub = jax.random.fold_in(keys[3], i)
        mk = (functools.partial(_rec_params, cfg=cfg, dt=dt) if kind == "rec"
              else functools.partial(_attn_params, cfg=cfg, dt=dt))
        tail_p[f"blk{i}"] = mk(sub)
        tail_p[f"mlp{i}"] = _mlp_params(jax.random.fold_in(keys[4], i), cfg, dt)
    p["tail"] = tail_p
    return p


# ------------------------------------------------------------------ RG-LRU
def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. u: (B,T,W); w: (cw, W)."""
    cw = w.shape[0]
    out = jnp.zeros_like(u, shape=u.shape)
    for j in range(cw):
        shifted = jnp.pad(u, [(0, 0), (j, 0), (0, 0)])[:, : u.shape[1]]
        out = out + shifted * w[j][None, None, :]
    return out + b[None, None, :]


def _rglru_gates(rp: Dict, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (a, beta·i·u) — the linear-recurrence coefficients, fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ rp["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ rp["w_i"].astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(rp["lam"])[None, None, :] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * uf


def rglru_scan(rp: Dict, u: jax.Array, h0: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Parallel associative scan over time. u: (B,T,W) -> (h (B,T,W), h_T)."""
    a, b = _rglru_gates(rp, u)
    if h0 is not None:
        # fold the incoming state into the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_step(rp: Dict, u: jax.Array, h_prev: jax.Array) -> jax.Array:
    """Single decode step. u: (B,1,W), h_prev: (B,W) -> h (B,W)."""
    a, b = _rglru_gates(rp, u)
    return a[:, 0] * h_prev.astype(jnp.float32) + b[:, 0]


# ------------------------------------------------------------------ blocks
def _rec_block(rp: Dict, cfg: ModelConfig, x: jax.Array,
               h0: Optional[jax.Array] = None,
               conv_state: Optional[jax.Array] = None, decode: bool = False):
    """Griffin recurrent temporal block.  Returns (out, h_T, conv_state)."""
    h = cm.apply_norm(x, rp["ln"], cfg.norm_type)
    y = cm.gelu(h @ rp["w_y"])
    u = h @ rp["w_x"]
    cw = cfg.conv_width
    if decode:
        # conv over the last cw inputs: state holds previous cw-1 u's
        hist = jnp.concatenate([conv_state, u], axis=1)     # (B, cw, W)
        # hist[-1] is u_t and the train conv is out_t = Σ_j w[j]·u_{t-j},
        # so the kernel applies reversed over the history window.
        conv = (hist * rp["conv_w"][::-1][None]).sum(axis=1, keepdims=True) \
            + rp["conv_b"][None, None, :]
        new_conv_state = hist[:, 1:]
        h_new = rglru_step(rp, conv, h0)
        out = (y * h_new[:, None].astype(y.dtype)) @ rp["w_o"]
        return x + out, h_new, new_conv_state
    conv = _causal_conv(u, rp["conv_w"], rp["conv_b"])
    rec, h_last = rglru_scan(rp, conv, h0)
    out = (y * rec) @ rp["w_o"]
    # conv state for subsequent decode: last cw-1 raw inputs
    new_conv_state = u[:, -(cw - 1):]
    return x + out, h_last, new_conv_state


def _attn_block_train(ap: Dict, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, q_chunk: int, kv_chunk: int):
    b, s, _ = x.shape
    h = cm.apply_norm(x, ap["ln"], cfg.norm_type)
    q = cm.shard(h @ ap["wq"], "batch", None, "model")
    k = h @ ap["wk"]
    v = h @ ap["wv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    attn = flash_attention(q, k, v, causal=True, window=cfg.local_window,
                           q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = attn.reshape(b, s, cfg.q_dim) @ ap["wo"]
    return x + out, k, v


def _attn_block_decode(ap: Dict, cfg: ModelConfig, x: jax.Array,
                       kc: jax.Array, vc: jax.Array, length: jax.Array):
    b = x.shape[0]
    cap = kc.shape[1]
    h = cm.apply_norm(x, ap["ln"], cfg.norm_type)
    pos = jnp.broadcast_to(length.reshape(1, 1), (b, 1))
    q = (h @ ap["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (h @ ap["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ ap["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    q = cm.apply_rope(q, pos, cfg.rope_theta)
    k = cm.apply_rope(k, pos, cfg.rope_theta)
    slot = jnp.mod(length, cap)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
    attn = decode_attention(q, kc, vc, jnp.minimum(length + 1, cap))
    out = attn.reshape(b, 1, cfg.q_dim) @ ap["wo"]
    return x + out, kc, vc


def _mlp_block(mp: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = cm.apply_norm(x, mp["ln"], cfg.norm_type)
    g = cm.shard(h @ mp["w_gate"], "batch", None, "model")
    u = cm.shard(h @ mp["w_up"], "batch", None, "model")
    return x + (cm.gelu(g) * u) @ mp["w_down"]


# ------------------------------------------------------------------ forward
def apply(params: Dict, cfg: ModelConfig, batch: Dict, *,
          q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    logits, _ = _forward(params, cfg, batch, q_chunk, kv_chunk,
                         want_cache=False)
    return logits


def _forward(params: Dict, cfg: ModelConfig, batch: Dict, q_chunk: int,
             kv_chunk: int, want_cache: bool, capacity: Optional[int] = None):
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    _, tail = group_structure(cfg)
    x, positions = dense.embed_inputs(params, cfg, batch)
    b, s = x.shape[:2]
    qc, kc_ = min(q_chunk, s), min(kv_chunk, s)
    win = min(cfg.local_window, capacity or cfg.local_window)

    def run_block(x, bp, mp, kind):
        """Returns (x, state_tuple) — state pieces padded to a uniform pytree."""
        if kind == "rec":
            x, h_last, conv_st = _rec_block(bp, cfg, x)
            st = {"h": h_last, "conv": conv_st}
        else:
            x, k, v = _attn_block_train(bp, cfg, x, positions, qc, kc_)
            if win <= s:
                k = jnp.roll(k[:, -win:], shift=s % win, axis=1)
                v = jnp.roll(v[:, -win:], shift=s % win, axis=1)
            else:
                padw = [(0, 0), (0, win - s), (0, 0), (0, 0)]
                k, v = jnp.pad(k, padw), jnp.pad(v, padw)
            st = {"k": k, "v": v}
        x = _mlp_block(mp, cfg, x)
        return x, st

    def group_step(x, gp):
        states = {}
        for i, kind in enumerate(pat):
            x, st = run_block(x, gp[f"blk{i}"], gp[f"mlp{i}"], kind)
            states[f"blk{i}"] = st
        return x, states

    body = jax.checkpoint(group_step)
    x, group_states = jax.lax.scan(body, x, params["groups"],
                                   unroll=runtime.scan_unroll())
    tail_states = []
    for i, kind in enumerate(tail):
        x, st = run_block(x, params["tail"][f"blk{i}"],
                          params["tail"][f"mlp{i}"], kind)
        tail_states.append(st)
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    if want_cache:
        logits = dense.logits_of(params, cfg, x[:, -1:])
        cache = {"groups": group_states, "tail": tail_states,
                 "length": jnp.asarray(s, jnp.int32)}
        return logits, cache
    return dense.logits_of(params, cfg, x), None


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            q_chunk: int = 1024, kv_chunk: int = 1024,
            capacity: Optional[int] = None):
    return _forward(params, cfg, batch, q_chunk, kv_chunk, want_cache=True,
                    capacity=capacity)


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, token: jax.Array):
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    _, tail = group_structure(cfg)
    x = jnp.take(params["embed"], token, axis=0)
    length = cache["length"]

    def run_block_decode(x, bp, mp, st, kind):
        if kind == "rec":
            x, h_new, conv_new = _rec_block(bp, cfg, x, h0=st["h"],
                                            conv_state=st["conv"], decode=True)
            st = {"h": h_new, "conv": conv_new}
        else:
            x, kc, vc = _attn_block_decode(bp, cfg, x, st["k"], st["v"], length)
            st = {"k": kc, "v": vc}
        return _mlp_block(mp, cfg, x), st

    def group_step(x, xs):
        gp, gst = xs
        new = {}
        for i, kind in enumerate(pat):
            x, st = run_block_decode(x, gp[f"blk{i}"], gp[f"mlp{i}"],
                                     gst[f"blk{i}"], kind)
            new[f"blk{i}"] = st
        return x, new

    x, new_groups = jax.lax.scan(group_step, x,
                                 (params["groups"], cache["groups"]),
                                 unroll=runtime.scan_unroll())
    new_tail = []
    for i, kind in enumerate(tail):
        x, st = run_block_decode(x, params["tail"][f"blk{i}"],
                                 params["tail"][f"mlp{i}"],
                                 cache["tail"][i], kind)
        new_tail.append(st)
    x = cm.apply_norm(x, params["final_norm"], cfg.norm_type)
    logits = dense.logits_of(params, cfg, x)
    return logits, {"groups": new_groups, "tail": new_tail,
                    "length": length + 1}
