"""Paged KV-cache executor (DESIGN.md §6.1, paged backend).

Four families of tests:

1.  Engine parity — the paged engine produces bit-identical greedy outputs
    to the contiguous slot engine (incl. under preemption from a tight
    pool), while admitting strictly more concurrent requests on the same
    KV budget, and random admit/evict/preempt churn keeps that true for
    random page/pool sizes (property-based; deeper sweep behind ``-m
    slow``).
2.  EOS regression — ``Engine`` reads EOS from ``ModelConfig.eos_id``; a
    prompt-configured EOS terminates decode in both paged and slot paths.
3.  Executor-layer invariants — headroom never negative, ``estimate()``
    monotone in queue depth, page accounting conserved through churny
    stepped serving.
4.  Sim-vs-engine agreement — the simulated ``TokenBucketExecutor`` in
    page mode and the real paged engine admit/deny identically on
    identical page budgets (both route through ``paged_admit_ok``), and
    ``go_offline`` churn drains paged nodes with their pages reclaimed.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Network, Node, NodePolicy
from repro.core.node import QueuedRequest
from repro.sim import (BackendProfile, EventLoop, TokenBucketExecutor,
                       make_profile)
from repro.sim.executor import paged_admit_ok, pages_for
from repro.sim.workload import Request


def _qr(rid, prompt, output, t=0.0):
    return QueuedRequest(
        Request(rid=rid, origin="n", arrival=t, prompt_tokens=prompt,
                output_tokens=output, slo_s=600.0),
        enqueue_time=t, delegated=False, origin_node="n")


class _Harness:
    """A TokenBucketExecutor on a bare event loop, recording completions."""

    def __init__(self, profile, page_size=None):
        self.loop = EventLoop()
        self.ex = TokenBucketExecutor(profile, page_size=page_size)
        self.done = {}
        self.ex.bind(self.loop, self._cb)

    def _cb(self, qr, started_at, first_token_at):
        self.done[qr.req.rid] = dict(finish=self.loop.now,
                                     started=started_at,
                                     first_token=first_token_at)


# ---------------------------------------------------------------------------
# shared pure-rule unit tests (no model, no loop)
# ---------------------------------------------------------------------------

class TestPagedAdmissionRule:
    def test_pages_for(self):
        assert pages_for(1, 16) == 1
        assert pages_for(16, 16) == 1
        assert pages_for(17, 16) == 2
        assert pages_for(0, 16) == 1          # every sequence owns >= 1 page

    @given(free=st.integers(0, 64), prompt=st.integers(1, 2048),
           page=st.sampled_from([8, 16, 32, 64]))
    @settings(max_examples=40, deadline=None)
    def test_rule_properties(self, free, prompt, page):
        # an empty backend always admits; a resident one admits iff the
        # prompt's pages fit the free pool
        assert paged_admit_ok(free, prompt, page, resident=False)
        assert paged_admit_ok(free, prompt, page, resident=True) == (
            pages_for(prompt, page) <= free)


# ---------------------------------------------------------------------------
# real-engine parity
# ---------------------------------------------------------------------------

_MODEL_CACHE = {}


def _smoke_model():
    """Memoized smoke model — also reachable from @given property tests,
    whose wrappers the hypothesis shim makes opaque to fixture injection."""
    if "cp" not in _MODEL_CACHE:
        import jax
        from repro.configs import get_config
        from repro.models import registry
        cfg = get_config("qwen3-8b").smoke().replace(dtype="float32")
        _MODEL_CACHE["cp"] = (cfg, registry.init(jax.random.PRNGKey(0), cfg))
    return _MODEL_CACHE["cp"]


@pytest.fixture(scope="module")
def setup():
    return _smoke_model()


def _mk_reqs(seed, n=4, max_prompt=24, max_new_hi=10):
    from repro.serving import GenRequest
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = int(rng.integers(5, max_prompt + 1))
        out.append(GenRequest(
            rid=f"r{i}",
            tokens=rng.integers(2, 400, size=plen).astype(np.int32),
            max_new=int(rng.integers(2, max_new_hi + 1))))
    return out


def _results_by_rid(reqs):
    return {r.rid: np.asarray(r.result) for r in reqs}


class TestPagedEngineParity:
    def test_paged_matches_slot_under_preemption(self, setup):
        """A pool too small for the offered load forces preempt-and-requeue
        mid-decode; greedy outputs must still be bit-identical."""
        from repro.serving import Engine
        cfg, params = setup
        slot = Engine(cfg, params, max_batch=2, bucket=16)
        paged = Engine(cfg, params, max_batch=4, bucket=16, paged=True,
                       page_size=16, num_pages=4)
        rs = slot.serve(_mk_reqs(7, n=5, max_new_hi=16))
        rp = paged.serve(_mk_reqs(7, n=5, max_new_hi=16))
        a, b = _results_by_rid(rs), _results_by_rid(rp)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert paged.stats.preempted > 0          # the tight pool actually bit
        snap = paged.load_snapshot()
        assert snap["pages_used"] == 0            # everything reclaimed

    def test_paged_admits_more_concurrency_same_kv_budget(self, setup):
        """Acceptance: same KV token budget, bit-identical greedy outputs,
        strictly more concurrently admitted requests under paging (admission
        charges prompt pages, not prompt+max_new reservations)."""
        from repro.serving import Engine
        cfg, params = setup
        reqs = _mk_reqs(3, n=6, max_prompt=14, max_new_hi=10)
        slot = Engine(cfg, params, max_batch=2, bucket=16)
        rs = slot.serve([r for r in reqs])
        # slot engine reserved pad(prompt)+pad(max_new) per slot; hand the
        # paged engine the same total KV as pages
        budget = slot.load_snapshot()["kv_budget"]
        paged = Engine(cfg, params, max_batch=6, bucket=16, paged=True,
                       page_size=16, num_pages=budget // 16)
        rp = paged.serve(_mk_reqs(3, n=6, max_prompt=14, max_new_hi=10))
        a, b = _results_by_rid(rs), _results_by_rid(rp)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert paged.stats.peak_resident > slot.stats.peak_resident
        assert slot.stats.peak_resident == 2

    @given(page_size=st.sampled_from([8, 16]), pool=st.integers(4, 8),
           seed=st.integers(0, 10**6))
    @settings(max_examples=3, deadline=None)
    def test_random_churn_parity_paged_vs_slot(self, page_size, pool, seed):
        """Random page/pool sizes and workloads: admit/evict/preempt churn
        in the paged engine never changes greedy outputs vs slot batching."""
        from repro.serving import Engine
        cfg, params = _smoke_model()
        slot = Engine(cfg, params, max_batch=2, bucket=16)
        paged = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                       page_size=page_size, num_pages=pool)
        rs = slot.serve(_mk_reqs(seed))
        rp = paged.serve(_mk_reqs(seed))
        a, b = _results_by_rid(rs), _results_by_rid(rp)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert paged.load_snapshot()["pages_used"] == 0

    @pytest.mark.slow
    @given(page_size=st.sampled_from([8, 16, 32]), pool=st.integers(3, 10),
           seed=st.integers(0, 10**6), max_batch=st.integers(2, 5))
    @settings(max_examples=8, deadline=None)
    def test_random_churn_parity_three_way_deep(self, page_size, pool,
                                                seed, max_batch):
        """Deeper sweep (``-m slow``): paged == slot == wave greedy outputs
        across random pool geometries and batch widths."""
        from repro.serving import Engine
        cfg, params = _smoke_model()
        slot = Engine(cfg, params, max_batch=2, bucket=16)
        wave = Engine(cfg, params, max_batch=2, bucket=16, continuous=False)
        paged = Engine(cfg, params, max_batch=max_batch, bucket=16,
                       paged=True, page_size=page_size, num_pages=pool)
        outs = [_results_by_rid(e.serve(_mk_reqs(seed, n=5, max_new_hi=14)))
                for e in (slot, wave, paged)]
        for rid in outs[0]:
            np.testing.assert_array_equal(outs[0][rid], outs[1][rid])
            np.testing.assert_array_equal(outs[0][rid], outs[2][rid])


class TestConfiguredEos:
    """Engine.eos_id comes from ModelConfig (regression for the hard-coded
    ``eos_id = 1``): a prompt-configured EOS terminates decode early in both
    the paged and the contiguous slot path."""

    @pytest.mark.parametrize("paged", [False, True])
    def test_configured_eos_terminates_decode(self, setup, paged):
        from repro.serving import Engine, GenRequest
        cfg, params = setup
        prompt = np.random.default_rng(11).integers(2, 400, size=12) \
            .astype(np.int32)

        def run(cfg_run, max_new=10):
            kw = dict(paged=True, page_size=16) if paged else {}
            eng = Engine(cfg_run, params, max_batch=2, bucket=16, **kw)
            assert eng.eos_id == cfg_run.eos_id
            (r,) = eng.serve([GenRequest(rid="a", tokens=prompt.copy(),
                                         max_new=max_new)])
            return list(r.result)

        base = run(cfg)
        assert len(base) == 10                   # ran to budget, no EOS hit
        # pick an emitted token whose first occurrence is not at step 0 and
        # declare it EOS; decode must now stop right before it
        tok = next(t for t in base[1:] if base.index(t) >= 1)
        cut = base.index(tok)
        early = run(cfg.replace(eos_id=int(tok)))
        assert early == base[:cut]
        assert len(early) < len(base)


# ---------------------------------------------------------------------------
# int8 KV pages (DESIGN.md §6.1-paged, quantized pools)
# ---------------------------------------------------------------------------

class TestQuantizedPages:
    """The int8 page pools must be invisible to the paging machinery:
    quantized-paged generations match quantized-slot bit-for-bit (the
    rounding is pinned by kernel tolerance oracles; THESE tests pin the
    block-table indirection), the shared ``quantized_pages`` rule doubles
    every capacity report, and preemption round-trips reproduce the same
    quantized tokens."""

    def test_quant_paged_matches_quant_slot_bitwise(self, setup):
        from repro.serving import Engine
        cfg, params = setup
        qcfg = cfg.replace(kv_quant=True)
        slot = Engine(qcfg, params, max_batch=2, bucket=16)
        paged = Engine(qcfg, params, max_batch=3, bucket=16, paged=True,
                       page_size=16, num_pages=8)
        rs = slot.serve(_mk_reqs(7, n=4, max_new_hi=10))
        rp = paged.serve(_mk_reqs(7, n=4, max_new_hi=10))
        a, b = _results_by_rid(rs), _results_by_rid(rp)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert paged.load_snapshot()["pages_used"] == 0

    def test_quant_preemption_roundtrips_same_tokens(self, setup):
        """LIFO preempt-and-requeue on an int8 pool: the greedy restart
        re-quantizes the same prompt through the same pipeline, so the
        reproduced tokens are bit-identical to the quantized-slot run."""
        from repro.serving import Engine
        cfg, params = setup
        qcfg = cfg.replace(kv_quant=True)
        slot = Engine(qcfg, params, max_batch=2, bucket=16)
        # num_pages=2 doubles to 4 usable pages — tight enough to preempt
        paged = Engine(qcfg, params, max_batch=4, bucket=16, paged=True,
                       page_size=16, num_pages=2)
        rs = slot.serve(_mk_reqs(7, n=5, max_new_hi=16))
        rp = paged.serve(_mk_reqs(7, n=5, max_new_hi=16))
        a, b = _results_by_rid(rs), _results_by_rid(rp)
        for rid in a:
            np.testing.assert_array_equal(a[rid], b[rid])
        assert paged.stats.preempted > 0          # the tight pool actually bit
        assert paged.load_snapshot()["pages_used"] == 0

    def test_quantized_pages_rule_shared_by_sim_and_engine(self, setup):
        """THE capacity rule: the same nominal pool reports 2x pages on
        both backends when quantized — sim and engine must agree or their
        admission decisions drift."""
        from repro.serving import Engine
        from repro.sim.executor import quantized_pages
        assert quantized_pages(8, False) == 8
        assert quantized_pages(8, True) == 16
        cfg, params = setup
        eng = Engine(cfg.replace(kv_quant=True), params, max_batch=2,
                     bucket=16, paged=True, page_size=16, num_pages=8)
        sim = TokenBucketExecutor(BackendProfile(
            prefill_tps=1e4, decode_tps=100.0, saturation=2,
            max_concurrency=8, quality=0.5, kv_token_budget=16 * 8),
            page_size=16, kv_quant=True)
        assert sim.pages_total == 16 == eng.load_snapshot()["pages_total"]

    def test_quant_page_accounting_conserved_under_churn(self, setup):
        """Stepped churny serving on int8 pools: the one free list covers
        page and scale pools alike, so pages_used + free_pages ==
        pages_total at every step and the pool fully drains."""
        from repro.serving import Engine
        cfg, params = setup
        eng = Engine(cfg.replace(kv_quant=True), params, max_batch=3,
                     bucket=16, paged=True, page_size=8, num_pages=5)
        for r in _mk_reqs(23, n=6, max_new_hi=12):
            eng.submit(r)
        while eng.has_work():
            eng.step()
            snap = eng.load_snapshot()
            assert snap["pages_used"] + snap["free_pages"] \
                == snap["pages_total"]
            assert snap["kv_used"] == snap["pages_used"] * snap["page_size"]
        assert eng.load_snapshot()["pages_used"] == 0


# ---------------------------------------------------------------------------
# executor-layer invariants
# ---------------------------------------------------------------------------

PAGED_PROF = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                            max_concurrency=8, quality=0.5,
                            kv_token_budget=1024)


class TestExecutorInvariants:
    @given(ops=st.lists(st.integers(1, 400), min_size=1, max_size=12),
           page=st.sampled_from([16, 32, 64]),
           dt=st.floats(0.0, 3.0))
    @settings(max_examples=25, deadline=None)
    def test_headroom_never_negative(self, ops, page, dt):
        """Random admit sequences + time advancement: every load() snapshot
        keeps both headrooms in [0, 1] and the counts non-negative."""
        h = _Harness(PAGED_PROF, page_size=page)
        t = 0.0
        for prompt in ops:
            h.ex.admit(_qr(f"p{t}-{prompt}", prompt, prompt, t=t))
            t += dt
            h.loop.run(until=t)
            ld = h.ex.load()
            assert 0.0 <= ld.kv_headroom <= 1.0
            assert 0.0 <= ld.page_headroom <= 1.0
            assert ld.pages_used >= 0 and ld.kv_used >= 0
            assert ld.pending_prefill_tokens >= 0
            assert ld.pending_decode_tokens >= 0
        h.loop.run()
        ld = h.ex.load()
        assert ld.pages_used == 0 and ld.kv_used == 0   # all reclaimed

    @pytest.mark.parametrize("page", [None, 32])
    def test_estimate_monotone_in_queue_depth(self, page):
        """estimate() must be weakly increasing in the number of admitted
        streams — more co-residents can only slow a hypothetical request."""
        h = _Harness(make_profile(), page_size=page)
        prev = 0.0
        for i in range(12):
            est = h.ex.estimate(256, 512)
            assert est >= prev
            prev = est
            assert h.ex.admit(_qr(f"r{i}", 64, 64))

    def test_engine_page_accounting_conserved(self, setup):
        """Stepped churny serving: pages_used + free_pages == pages_total at
        every engine step, and the pool fully drains."""
        from repro.serving import Engine
        cfg, params = setup
        eng = Engine(cfg, params, max_batch=3, bucket=16, paged=True,
                     page_size=8, num_pages=9)
        for r in _mk_reqs(23, n=6, max_new_hi=12):
            eng.submit(r)
        while eng.has_work():
            eng.step()
            snap = eng.load_snapshot()
            assert snap["pages_used"] + snap["free_pages"] \
                == snap["pages_total"]
            assert snap["pages_used"] >= 0
            assert snap["kv_used"] == snap["pages_used"] * snap["page_size"]
        assert eng.load_snapshot()["pages_used"] == 0


# ---------------------------------------------------------------------------
# sim-vs-engine agreement + churn
# ---------------------------------------------------------------------------

class TestSimEngineAgreement:
    def test_admission_decisions_agree_on_identical_page_budget(self, setup):
        """The simulated page-mode executor and the real paged engine (via
        the page-gated EngineExecutor) must produce the same admit/deny
        sequence for the same page budget — they share paged_admit_ok."""
        from repro.serving import Engine, EngineExecutor, GenRequest
        cfg, params = setup
        page, pool = 16, 8
        prof = BackendProfile(prefill_tps=1e4, decode_tps=100.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=page * pool)
        sim = _Harness(prof, page_size=page)
        eng = Engine(cfg, params, max_batch=8, bucket=16, paged=True,
                     page_size=page, num_pages=pool)
        ex = EngineExecutor(eng, gate_on_pages=True)
        ex.bind(None, lambda r, st_, ft: None)
        rng = np.random.default_rng(5)
        sim_dec, eng_dec = [], []
        for i, plen in enumerate((40, 30, 50, 20)):     # pages 3, 2, 4, 2
            sim_dec.append(sim.ex.admit(_qr(f"s{i}", plen, 64)))
            ok = ex.admit(GenRequest(
                rid=f"e{i}", tokens=rng.integers(2, 400, size=plen)
                .astype(np.int32), max_new=64))
            eng_dec.append(ok)
            if ok:
                ex.step()         # prefill claims the prompt pages for real
        assert sim_dec == eng_dec == [True, True, False, True]
        assert ex.load().pages_used == sim.ex.load().pages_used == 7
        assert ex.load().pages_total == sim.ex.load().pages_total == pool

    def test_go_offline_reclaims_doubled_quantized_pool(self):
        """Churn on an int8 page pool: the doubled capacity is visible in
        every load snapshot and every page (and with it its scale-pool
        row — one free list covers both) is reclaimed after the node
        drains offline."""
        net = Network(mode="single", seed=0, init_balance=100.0)
        prof = BackendProfile(prefill_tps=1e4, decode_tps=50.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=4096)
        net.add_node(Node(
            "n1", prof, policy=NodePolicy(),
            executor_factory=lambda node: TokenBucketExecutor(
                node.profile, page_size=64, kv_quant=True)))
        net.add_node(Node("n2", make_profile(), policy=NodePolicy()))
        reqs = [Request(rid=f"r{i}", origin="n1", arrival=0.1 * i,
                        prompt_tokens=500, output_tokens=1000, slo_s=600.0)
                for i in range(10)]
        net.loop.schedule(5.0, lambda: net.nodes["n1"].go_offline())
        m = net.run(reqs, until=500.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == 10                          # nothing stranded
        ld = net.nodes["n1"].executor.load()
        assert ld.pages_total == 2 * (4096 // 64)       # quantized_pages rule
        assert ld.pages_used == 0 and ld.page_headroom == 1.0

    def test_go_offline_drains_paged_node_with_pages_reclaimed(self):
        """Churn: a paged node going offline hands queued requests back to
        the network; its in-flight streams drain and every page returns to
        the pool."""
        net = Network(mode="single", seed=0, init_balance=100.0)
        prof = BackendProfile(prefill_tps=1e4, decode_tps=50.0, saturation=2,
                              max_concurrency=8, quality=0.5,
                              kv_token_budget=4096)
        net.add_node(Node(
            "n1", prof, policy=NodePolicy(),
            executor_factory=lambda node: TokenBucketExecutor(
                node.profile, page_size=64)))
        net.add_node(Node("n2", make_profile(), policy=NodePolicy()))
        reqs = [Request(rid=f"r{i}", origin="n1", arrival=0.1 * i,
                        prompt_tokens=500, output_tokens=1000, slo_s=600.0)
                for i in range(10)]
        net.loop.schedule(5.0, lambda: net.nodes["n1"].go_offline())
        m = net.run(reqs, until=500.0)
        user = [c for c in m.completed if not c.is_duel_extra]
        assert len(user) == 10                          # nothing stranded
        assert net.nodes["n1"].queue_len == 0
        assert any(c.executor == "n2" for c in user)    # drained to the peer
        ld = net.nodes["n1"].executor.load()
        assert ld.pages_used == 0 and ld.page_headroom == 1.0
