from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.training.train_step import (cross_entropy, init_state, loss_fn,
                                       make_train_step, state_shape)
