"""repro.obs — the observability plane (DESIGN.md §Observability).

One home for the three telemetry primitives every layer shares:

* :mod:`repro.obs.tracer` — per-request lifecycle spans (``route.decide``,
  ``executor.queue``/``admit``/``preempt``, ``engine.prefill`` /
  ``decode_step`` / ``spec_verify``, ``disagg.handoff``) recorded against
  either the simulator clock or the wall clock, cheap no-op when disabled.
* :mod:`repro.obs.metrics` — labeled counters/gauges/histograms the
  ad-hoc accumulators (``Network.msg_counts``, drop events, preemptions,
  prefix hit rates) feed through, snapshotable as JSON.
* :mod:`repro.obs.export` — Chrome/Perfetto ``trace_event`` JSON writer
  and the plain-text per-request latency-breakdown report.

Instrumented layers (network/node/executor/engine) never touch
``time.perf_counter`` or construct ``Span`` directly — they call
:func:`wall_now` / :meth:`Tracer.wall` / :meth:`Tracer.span`, which is
what the ``obs-lint`` checker (DESIGN.md §7) enforces.
"""

from repro.obs.export import (breakdown_report, latency_breakdown,
                              to_chrome_trace, write_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry, set_registry)
from repro.obs.tracer import (SIM, WALL, Span, Tracer, WallSpan, get_tracer,
                              set_tracer, wall_now)

__all__ = [
    "SIM", "WALL", "Span", "Tracer", "WallSpan", "get_tracer", "set_tracer",
    "wall_now",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "set_registry",
    "to_chrome_trace", "write_chrome_trace", "latency_breakdown",
    "breakdown_report",
]
