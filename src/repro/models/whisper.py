"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv2 frontend is a STUB per the assignment carve-out:
``input_specs`` feeds precomputed frame embeddings (B, encoder_seq, d) — the
transformer encoder, the decoder (self + cross attention), and the serving /
training substrate around them are fully implemented.

Uses learned positional embeddings, LayerNorm, GeLU MLPs, biased projections
(as in the original).  Decode caches: per-layer self-attention KV ring plus
per-layer cross-attention K/V computed once from the encoder output.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import runtime
from repro.models import dense
from repro.models.attention import decode_attention, flash_attention
from repro.models.config import ModelConfig

MAX_TARGET_POS = 4096   # learned decoder positions (real Whisper: 448)


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def init(key: jax.Array, cfg: ModelConfig) -> Dict:
    dt = _dt(cfg)
    d, f = cfg.d_model, cfg.d_ff
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    keys = jax.random.split(key, 12)

    def attn(k, kv_dim):
        ks = jax.random.split(k, 4)
        return {
            "ln": cm.norm_params(d, "layernorm", dt),
            "wq": cm.dense_init(ks[0], d, cfg.q_dim, dt),
            "bq": jnp.zeros((cfg.q_dim,), dt),
            "wk": cm.dense_init(ks[1], d, kv_dim, dt),
            "wv": cm.dense_init(ks[2], d, kv_dim, dt),
            "bv": jnp.zeros((kv_dim,), dt),
            "wo": cm.dense_init(ks[3], cfg.q_dim, d, dt),
            "bo": jnp.zeros((d,), dt),
        }

    def mlp(k):
        ks = jax.random.split(k, 2)
        return {
            "ln": cm.norm_params(d, "layernorm", dt),
            "w_up": cm.dense_init(ks[0], d, f, dt),
            "b_up": jnp.zeros((f,), dt),
            "w_down": cm.dense_init(ks[1], f, d, dt),
            "b_down": jnp.zeros((d,), dt),
        }

    def stack(fn, k, n):
        ks = jax.random.split(k, n)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn(kk) for kk in ks])

    return {
        "enc_pos": (jax.random.normal(keys[0], (cfg.encoder_seq, d)) * 0.01
                    ).astype(dt),
        "enc_attn": stack(lambda k: attn(k, cfg.kv_dim), keys[1], Le),
        "enc_mlp": stack(mlp, keys[2], Le),
        "enc_norm": cm.norm_params(d, "layernorm", dt),
        "embed": cm.embed_init(keys[3], cfg.padded_vocab, d, dt),
        "dec_pos": (jax.random.normal(keys[4], (MAX_TARGET_POS, d)) * 0.01
                    ).astype(dt),
        "dec_self": stack(lambda k: attn(k, cfg.kv_dim), keys[5], Ld),
        "dec_cross": stack(lambda k: attn(k, cfg.kv_dim), keys[6], Ld),
        "dec_mlp": stack(mlp, keys[7], Ld),
        "dec_norm": cm.norm_params(d, "layernorm", dt),
    }   # lm head is tied to the token embedding (as in Whisper)


def _heads(cfg, x, n):
    return x.reshape(x.shape[0], x.shape[1], n, cfg.head_dim)


def _bias(b):
    # rank-3 activations + rank-1 bias: broadcast explicitly (the test
    # suite runs with rank promotion set to "raise")
    return b[None, None, :]


def _attn_proj(ap, cfg, hq, hkv):
    q = _heads(cfg, hq @ ap["wq"] + _bias(ap["bq"]), cfg.n_heads)
    k = _heads(cfg, hkv @ ap["wk"], cfg.n_kv_heads)
    v = _heads(cfg, hkv @ ap["wv"] + _bias(ap["bv"]), cfg.n_kv_heads)
    return q, k, v


def encode(params: Dict, cfg: ModelConfig, embeds: jax.Array) -> jax.Array:
    """embeds: (B, encoder_seq, d) frame embeddings from the (stub) frontend."""
    x = embeds.astype(_dt(cfg)) + params["enc_pos"][None, : embeds.shape[1]]
    x = cm.shard(x, "batch", "seq", None)
    s = x.shape[1]

    def step(x, lp):
        ap, mp = lp
        h = cm.apply_norm(x, ap["ln"], "layernorm")
        q, k, v = _attn_proj(ap, cfg, h, h)
        a = flash_attention(q, k, v, causal=False,
                            q_chunk=min(512, s), kv_chunk=min(512, s))
        x = x + a.reshape(*x.shape[:2], cfg.q_dim) @ ap["wo"] + _bias(ap["bo"])
        h2 = cm.apply_norm(x, mp["ln"], "layernorm")
        x = x + (cm.gelu(h2 @ mp["w_up"] + _bias(mp["b_up"])) @ mp["w_down"]
                 + _bias(mp["b_down"]))
        return cm.shard(x, "batch", "seq", None), None

    x, _ = jax.lax.scan(jax.checkpoint(step), x,
                        (params["enc_attn"], params["enc_mlp"]),
                        unroll=runtime.scan_unroll())
    return cm.apply_norm(x, params["enc_norm"], "layernorm")


def _decoder_block(lp, cfg, x, enc_out, positions, q_chunk):
    sp, cp, mp = lp
    s = x.shape[1]
    h = cm.apply_norm(x, sp["ln"], "layernorm")
    q, k, v = _attn_proj(sp, cfg, h, h)
    a = flash_attention(q, k, v, causal=True, q_chunk=min(q_chunk, s),
                        kv_chunk=min(q_chunk, s))
    x = x + a.reshape(*x.shape[:2], cfg.q_dim) @ sp["wo"] + _bias(sp["bo"])
    h = cm.apply_norm(x, cp["ln"], "layernorm")
    q, k, v = _attn_proj(cp, cfg, h, enc_out)
    a = flash_attention(q, k, v, causal=False, q_chunk=min(q_chunk, s),
                        kv_chunk=min(512, enc_out.shape[1]))
    x = x + a.reshape(*x.shape[:2], cfg.q_dim) @ cp["wo"] + _bias(cp["bo"])
    h = cm.apply_norm(x, mp["ln"], "layernorm")
    x = (x + cm.gelu(h @ mp["w_up"] + _bias(mp["b_up"])) @ mp["w_down"]
         + _bias(mp["b_down"]))
    return cm.shard(x, "batch", "seq", None)


def apply(params: Dict, cfg: ModelConfig, batch: Dict, *,
          q_chunk: int = 1024, **_) -> jax.Array:
    """batch: {"encoder_embeds": (B,Se,d), "tokens": (B,St)} -> logits."""
    enc_out = encode(params, cfg, batch["encoder_embeds"])
    tokens = batch["tokens"]
    s = tokens.shape[1]
    pos = jnp.arange(s) % MAX_TARGET_POS
    x = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][pos][None]
    x = cm.shard(x, "batch", "seq", None)
    fn = functools.partial(_decoder_block, cfg=cfg, enc_out=enc_out,
                           positions=pos, q_chunk=q_chunk)
    body = jax.checkpoint(lambda c, lp: (fn(lp, x=c), None))
    x, _ = jax.lax.scan(body, x, (params["dec_self"], params["dec_cross"],
                                  params["dec_mlp"]),
                        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["dec_norm"], "layernorm")
    return cm.shard(x @ params["embed"].T, "batch", None, "model")


def prefill(params: Dict, cfg: ModelConfig, batch: Dict, *,
            q_chunk: int = 1024, capacity: Optional[int] = None, **_):
    """Encode audio + run the decoder prompt; build self/cross caches."""
    enc_out = encode(params, cfg, batch["encoder_embeds"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    cap = max(s, capacity or s)
    pos = jnp.arange(s) % MAX_TARGET_POS
    x = jnp.take(params["embed"], tokens, axis=0) + params["dec_pos"][pos][None]

    def step(x, lp):
        sp, cp, mp = lp
        h = cm.apply_norm(x, sp["ln"], "layernorm")
        q, k, v = _attn_proj(sp, cfg, h, h)
        a = flash_attention(q, k, v, causal=True, q_chunk=min(q_chunk, s),
                            kv_chunk=min(q_chunk, s))
        x = x + a.reshape(b, s, cfg.q_dim) @ sp["wo"] + _bias(sp["bo"])
        h = cm.apply_norm(x, cp["ln"], "layernorm")
        qc, kc, vc = _attn_proj(cp, cfg, h, enc_out)
        a = flash_attention(qc, kc, vc, causal=False, q_chunk=min(q_chunk, s),
                            kv_chunk=min(512, enc_out.shape[1]))
        x = x + a.reshape(b, s, cfg.q_dim) @ cp["wo"] + _bias(cp["bo"])
        h = cm.apply_norm(x, mp["ln"], "layernorm")
        x = (x + cm.gelu(h @ mp["w_up"] + _bias(mp["b_up"])) @ mp["w_down"]
             + _bias(mp["b_down"]))
        padw = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
        return x, (jnp.pad(k, padw), jnp.pad(v, padw), kc, vc)

    x, (ks, vs, kcs, vcs) = jax.lax.scan(
        jax.checkpoint(step), x,
        (params["dec_self"], params["dec_cross"], params["dec_mlp"]),
        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["dec_norm"], "layernorm")
    logits = (x[:, -1:] @ params["embed"].T)
    cache = {"k": ks, "v": vs, "cross_k": kcs, "cross_v": vcs,
             "length": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, token: jax.Array):
    length = cache["length"]
    x = (jnp.take(params["embed"], token, axis=0)
         + params["dec_pos"][jnp.mod(length, MAX_TARGET_POS)][None, None])

    def step(x, xs):
        (sp, cp, mp), kc, vc, ck, cv = xs
        b = x.shape[0]
        cap = kc.shape[1]
        h = cm.apply_norm(x, sp["ln"], "layernorm")
        q, k, v = _attn_proj(sp, cfg, h, h)
        slot = jnp.mod(length, cap)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
        a = decode_attention(q, kc, vc, jnp.minimum(length + 1, cap))
        x = x + a.reshape(b, 1, cfg.q_dim) @ sp["wo"] + _bias(sp["bo"])
        h = cm.apply_norm(x, cp["ln"], "layernorm")
        q = _heads(cfg, h @ cp["wq"] + _bias(cp["bq"]), cfg.n_heads)
        a = decode_attention(q, ck, cv, jnp.asarray(ck.shape[1], jnp.int32))
        x = x + a.reshape(b, 1, cfg.q_dim) @ cp["wo"] + _bias(cp["bo"])
        h = cm.apply_norm(x, mp["ln"], "layernorm")
        x = (x + cm.gelu(h @ mp["w_up"] + _bias(mp["b_up"])) @ mp["w_down"]
             + _bias(mp["b_down"]))
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        step, x, ((params["dec_self"], params["dec_cross"], params["dec_mlp"]),
                  cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
        unroll=runtime.scan_unroll())
    x = cm.apply_norm(x, params["dec_norm"], "layernorm")
    logits = x @ params["embed"].T
    return logits, {"k": k_new, "v": v_new, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "length": length + 1}
