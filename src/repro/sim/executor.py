"""Pluggable request-execution backends (the node's Model Manager core).

The paper's nodes run vLLM/SGLang-style continuous-batching engines, so the
latency a request sees depends on the *time-varying* batch it shares the
accelerator with — not on a share frozen at admission.  This module defines
the Executor contract every backend implements (DESIGN.md §6.1).

The Executor contract
---------------------

An ``Executor`` is what a Node's Model Manager holds instead of an analytic
service-time formula:

* ``admit(item) -> bool``   — start executing ``item`` now if KV headroom
                              allows; ``False`` means "try again after a
                              completion" (the caller keeps it queued).
* ``load() -> ExecutorLoad``— point-in-time occupancy snapshot (streams,
                              remaining tokens per phase, KV/page budgets)
                              used by routing, probing, and rebalancing.
* ``estimate(p, o) -> s``   — expected service seconds for a hypothetical
                              (prompt, output) request admitted now.
* ``bind(loop, on_complete)``— attach the driving clock (an ``EventLoop``,
                              or ``None`` for wall-clock backends) and a
                              completion callback; the callback receives
                              ``(item, started_at, first_token_at)`` so the
                              caller can derive queue wait and TTFT.

Minimal usage example (simulated backend on a bare event loop)::

    from repro.sim import EventLoop, TokenBucketExecutor, make_profile

    loop, done = EventLoop(), []
    ex = TokenBucketExecutor(make_profile())
    ex.bind(loop, lambda item, started, first_tok: done.append(item))
    assert ex.admit(queued_request)      # False = KV headroom exhausted
    loop.run()                           # event-driven progress -> callback
    ex.load().kv_headroom                # snapshot for routing/probing

Backends in this module:

* ``TokenBucketExecutor``       — simulated continuous batching: token-level
  prefill then decode progress integrated piecewise-linearly by the
  ``EventLoop``, decode share recomputed on every membership change,
  admission gated by a KV *token* budget rather than a stream count.  At
  steady state (constant occupancy) it reproduces the analytic
  ``BackendProfile.service_time`` exactly; under bursts and churn,
  in-flight requests slow down and speed up as the batch shifts.  With
  ``page_size`` set, admission switches to the page-granularity rule
  shared with the real paged engine (``paged_admit_ok``): prompt pages
  must fit the free pool, decode pages accrue with generation progress.
  The sim does not model preemption — transient over-occupancy simply
  shows up as zero page headroom.  With ``prefix_cache`` additionally
  set, admission consults the shared hit rule (``prefix_hit_pages``,
  DESIGN.md §6.1-prefix): a request whose ``prefix_id`` is resident in
  the node's prefix LRU skips that many pages of prefill work, and the
  load snapshot reports ``cache_hit_rate``/``resident_prefixes`` so
  dispatch can route toward warm caches.
* ``SpecTokenBucketExecutor``  — simulated speculative decoding (DESIGN.md
  §6.1-spec): same admission as the plain bucket, but decode throughput is
  scaled by the analytic acceptance model
  ``spec_expected_tokens(alpha, k) / (1 + overhead)`` and the load
  snapshot reports ``expected_tokens_per_step`` so dispatch can route
  decode-heavy traffic toward speculation-enabled nodes.
* ``DisaggTokenBucketExecutor`` — simulated disaggregated prefill/decode
  (DESIGN.md §6.1-disagg): a prefill-only and a decode-only token bucket
  joined by an explicit KV-transfer cost model
  (``bytes = prompt_len * kv_bytes_per_token``, latency charged before
  decode admission).  Admission reserves the prompt's decode-side pages
  so every accepted transfer can eventually land.

The real-engine counterparts (``EngineExecutor``, slot-based continuous
batching over the JAX ``Engine``, ``SpecEngineExecutor``, draft/verify
speculative decoding over a spec-enabled paged ``Engine``, and
``DisaggEngineExecutor``, a paired prefill/decode engine with
page-granular KV handoff) live in ``repro.serving.executor``.

This module (plus ``servicemodel``) is the only sanctioned caller of
``BackendProfile.service_time`` — a grep-guard in ``tests/test_compat.py``
keeps frozen-share scheduling from creeping back in.
"""

from __future__ import annotations

import math
import zlib
from abc import ABC, abstractmethod
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Any, Callable, List, Optional, Tuple

from repro.obs import get_tracer
from repro.sim.events import EventLoop
from repro.sim.servicemodel import (DIGEST_STALENESS_TAU_S,
                                    KV_BYTES_PER_TOKEN, KV_TOKENS_PER_STREAM,
                                    PREFIX_FINGERPRINT_K, PREFIX_HIT_EMA_BETA,
                                    SPEC_ALPHA0, SPEC_K, SPEC_OVERHEAD,
                                    TRANSFER_BASE_S, TRANSFER_BYTES_PER_S,
                                    BackendProfile)

# completion callback: (item, started_at, first_token_at) in sim/wall time
CompletionFn = Callable[[Any, float, float], None]

# token-progress slack absorbing float error in rate*dt integration: 1e-6
# tokens is ~1e-8 s of decode — far below any latency we report
_EPS = 1e-6


def pages_for(tokens: int, page_size: int) -> int:
    """KV pages needed to hold ``tokens`` (every sequence owns >= 1 page)."""
    return max(1, -(-int(tokens) // int(page_size)))


def paged_admit_ok(free_pages: int, prompt_tokens: int, page_size: int,
                   resident: bool) -> bool:
    """THE paged admission rule, shared by the simulated and real backends
    (DESIGN.md §6.1, paged backend): a request is admitted when its
    *prompt* pages fit the free pool — its decode pages are claimed one at
    a time as it generates (preempt-and-requeue reclaims them under
    pressure).  An empty backend always admits one request so oversized
    prompts cannot deadlock the queue.
    """
    return (not resident) or pages_for(prompt_tokens, page_size) <= free_pages


def quantized_pages(num_pages: int, quantized: bool) -> int:
    """THE quantized-pool capacity rule, shared by the simulated and real
    backends (DESIGN.md §6.1-paged): int8 KV pages are half the bytes of
    fp pages, so the same HBM budget holds **2x the pages** — admission and
    preemption already meter pages, so capacity doubles with no further
    rule changes.  ``num_pages`` is the fp-page count of the budget; the
    scale pages ride in a parallel pool whose footprint (1/head_dim of the
    values) is treated as overhead, not metered capacity.
    """
    return int(num_pages) * 2 if quantized else int(num_pages)


def prefix_hit_pages(prompt_tokens: int, page_size: int,
                     matched_tokens: int) -> int:
    """THE prefix-cache hit rule, shared by the simulated and real backends
    (DESIGN.md §6.1-prefix): a page-aligned hash-chain lookup that matched
    ``matched_tokens`` of the prompt reuses that many *full* pages from the
    cache.  The prompt's final page is always recomputed — its fresh forward
    is what produces the first-token logits — so hits are capped at
    ``pages_for(prompt) - 1`` and the recomputed suffix is never empty.
    Partial pages never share (copy-on-write happens at page granularity:
    a mid-page divergence is simply a hash miss at that chain depth).
    """
    ps = max(1, int(page_size))
    full = max(0, int(matched_tokens)) // ps
    return max(0, min(full, pages_for(prompt_tokens, ps) - 1))


def prefix_fingerprint_id(prefix_id: str) -> int:
    """Stable 32-bit identity of a named shared prefix — what a
    ``LoadDigest.resident_prefixes`` fingerprint carries and what
    cache-affinity dispatch (DESIGN.md §6.1-prefix) compares a request's
    ``prefix_id`` against; kept checksum-cheap because routing computes it
    per dispatch decision."""
    return zlib.crc32(str(prefix_id).encode("utf-8"))


def spec_expected_tokens(alpha: float, k: int) -> float:
    """THE speculative-decoding acceptance model, shared by the simulated
    and real backends (DESIGN.md §6.1-spec): with per-token draft
    acceptance rate ``alpha`` and ``k`` draft tokens per verify step, the
    expected tokens emitted per target forward is the truncated geometric
    sum ``(1 - alpha^(k+1)) / (1 - alpha)`` — between 1 (every draft
    rejected: only the pending token survives) and ``k + 1`` (every draft
    accepted plus the bonus correction).
    """
    a = min(max(float(alpha), 0.0), 1.0)
    k = max(0, int(k))
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


@dataclass(frozen=True)
class ExecutorLoad:
    """Point-in-time snapshot of an executor's occupancy.

    ``active_streams`` are requests holding compute now; ``queued_streams``
    are admitted but waiting for a slot (real engine only).  Token counts
    are *remaining* work; ``kv_used``/``kv_budget`` express KV-memory
    pressure in tokens.  Paged backends additionally report page-pool
    occupancy (``pages_total`` stays 0 for contiguous backends).

    Disaggregated backends (DESIGN.md §6.1-disagg) split the budgets by
    phase: ``kv_used``/``kv_budget``/``pages_*`` track the *decode* pool
    (where KV lives long-term), ``prefill_kv_used``/``prefill_kv_budget``
    the prefill pool, and ``transfer_inflight`` counts streams handed off
    but not yet decode-admitted.  Colocated backends leave
    ``prefill_kv_budget`` at 0, so ``prefill_headroom`` and
    ``decode_headroom`` both collapse to ``kv_headroom`` — phase-aware
    dispatch (``Network._phase_pressure``) reads the two headrooms without
    caring which backend produced them.
    """

    active_streams: int
    queued_streams: int
    pending_prefill_tokens: int
    pending_decode_tokens: int
    kv_used: int
    kv_budget: int
    pages_used: int = 0
    pages_total: int = 0
    prefill_kv_used: int = 0
    prefill_kv_budget: int = 0   # 0 = colocated: both phases share kv_budget
    transfer_inflight: int = 0   # disagg: handed off, not yet decode-admitted
    handoff_bytes: int = 0       # disagg: cumulative KV bytes handed off
    # speculative backends (DESIGN.md §6.1-spec): expected tokens emitted
    # per target decode step, (1 - alpha^(k+1)) / (1 - alpha) for draft
    # acceptance rate alpha and depth k.  1.0 for non-speculative backends,
    # so dispatch can divide decode pressure by it unconditionally.
    expected_tokens_per_step: float = 1.0
    # prefix-caching backends (DESIGN.md §6.1-prefix): EMA of the fraction
    # of admitted prompt tokens served from the page cache, plus a
    # fingerprint of up to PREFIX_FINGERPRINT_K resident prefix identities
    # (prefix_fingerprint_id values, most recently touched first) so
    # cache-affinity dispatch can break near-ties toward the node already
    # holding a request's prefix.  0.0/() for cache-less backends.
    cache_hit_rate: float = 0.0
    resident_prefixes: Tuple[int, ...] = ()

    @property
    def kv_headroom(self) -> float:
        """Free fraction of the KV budget, in [0, 1]."""
        if self.kv_budget <= 0:
            return 1.0
        return max(0.0, 1.0 - self.kv_used / self.kv_budget)

    @property
    def page_headroom(self) -> float:
        """Free fraction of the KV page pool, in [0, 1] (1.0 = unpaged)."""
        if self.pages_total <= 0:
            return 1.0
        return max(0.0, 1.0 - self.pages_used / self.pages_total)

    @property
    def prefill_headroom(self) -> float:
        """Free fraction of the prefill-phase KV budget, in [0, 1].

        Colocated backends share one pool across phases, so this equals
        ``kv_headroom``; disaggregated backends report their dedicated
        prefill pool."""
        if self.prefill_kv_budget <= 0:
            return self.kv_headroom
        return max(0.0, 1.0 - self.prefill_kv_used / self.prefill_kv_budget)

    @property
    def decode_headroom(self) -> float:
        """Free fraction of the decode-phase KV budget, in [0, 1]
        (``kv_used``/``kv_budget`` track the decode pool for disaggregated
        backends, the shared pool for colocated ones)."""
        return self.kv_headroom


@dataclass(frozen=True)
class LoadDigest:
    """Compact, gossip-borne summary of an ``ExecutorLoad`` snapshot
    (DESIGN.md §6.2-gossip).

    This is what a node publishes about itself on every gossip round: just
    enough for a *remote* router to rank it — the two phase headrooms, the
    phase backlogs, the speculative speedup factor, the cumulative handoff
    byte counter (so observers can learn transfer rates from deltas), and
    the origin timestamp ``t`` that staleness discounting keys on.  It is
    deliberately a projection, not the full ``ExecutorLoad``: budgets and
    page counts stay node-local.

    Construction is confined to the executor layer — build digests via
    ``Executor.digest()`` / ``make_load_digest`` (enforced by the
    ``layering/digest-construction`` rule in ``repro.analysis``).
    """

    t: float                       # origin sim-time the snapshot was taken
    prefill_headroom: float
    decode_headroom: float
    pending_prefill_tokens: int
    pending_decode_tokens: int
    expected_tokens_per_step: float
    handoff_bytes: int
    # prefix caching (DESIGN.md §6.1-prefix): the hit-rate EMA and the
    # resident-prefix fingerprint travel with every digest, so a remote
    # router knows where a request's prefix is already warm without any
    # extra gossip traffic (the digest already piggybacks on heartbeats).
    cache_hit_rate: float = 0.0
    resident_prefixes: Tuple[int, ...] = ()


def make_load_digest(load: ExecutorLoad, now: float) -> LoadDigest:
    """Project an ``ExecutorLoad`` snapshot into its gossip digest."""
    return LoadDigest(
        t=float(now),
        prefill_headroom=load.prefill_headroom,
        decode_headroom=load.decode_headroom,
        pending_prefill_tokens=load.pending_prefill_tokens,
        pending_decode_tokens=load.pending_decode_tokens,
        expected_tokens_per_step=load.expected_tokens_per_step,
        handoff_bytes=load.handoff_bytes,
        cache_hit_rate=load.cache_hit_rate,
        resident_prefixes=load.resident_prefixes,
    )


def digest_staleness_weight(age_s: float,
                            tau_s: float = DIGEST_STALENESS_TAU_S) -> float:
    """THE staleness-discount rule, shared by routing and its sim twin
    (DESIGN.md §6.2-gossip): a digest of age ``age_s`` is trusted with
    weight ``exp(-age / tau)``; the pressure a router infers from it
    regresses toward the neutral prior as the weight decays, so a
    seconds-old digest still steers dispatch while a minutes-old one is
    as good as no information.
    """
    return math.exp(-max(0.0, float(age_s)) / float(tau_s))


class Executor(ABC):
    """Backend-agnostic execution contract held by a Node's Model Manager."""

    # trace identity: who emitted a span (DESIGN.md §Observability).  Set
    # by the owning Node at bind time; standalone executors keep "".
    owner: str = ""

    def digest(self, now: float) -> LoadDigest:
        """Gossip digest of the current load snapshot (DESIGN.md
        §6.2-gossip); the only sanctioned way to build a ``LoadDigest``
        outside this module."""
        return make_load_digest(self.load(), now)

    def bind(self, loop: Optional[EventLoop], on_complete: CompletionFn) -> None:
        """Attach the driving clock and the completion callback."""
        self._loop = loop
        self._on_complete = on_complete

    @property
    @abstractmethod
    def n_active(self) -> int:
        """Number of streams currently holding compute."""

    @abstractmethod
    def admit(self, item: Any) -> bool:
        """Start executing ``item`` if KV headroom allows; False = try later."""

    @abstractmethod
    def load(self) -> ExecutorLoad:
        """Snapshot of current occupancy (routing / probing / rebalance)."""

    @abstractmethod
    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        """Expected service seconds for a hypothetical request admitted now."""


class _Stream:
    """One in-flight request inside the TokenBucketExecutor."""

    __slots__ = ("item", "prompt_left", "output_left", "prompt_total",
                 "output_total", "kv_tokens", "decoding", "started_at",
                 "first_token_at")

    def __init__(self, item: Any, prompt: int, output: int, now: float,
                 cached_tokens: int = 0) -> None:
        self.item = item
        self.prompt_total = max(1, prompt)
        self.output_total = max(1, output)
        # prefix-cache hits (DESIGN.md §6.1-prefix) skip prefill *work* for
        # the cached pages; the stream still holds its full prompt's pages
        # (tokens_held charges prompt_total), so only latency changes.
        self.prompt_left = float(max(1, self.prompt_total - cached_tokens))
        self.output_left = float(self.output_total)
        self.kv_tokens = self.prompt_total + self.output_total
        self.decoding = False
        self.started_at = now
        self.first_token_at: Optional[float] = None

    def tokens_held(self) -> int:
        """KV tokens this stream physically occupies right now (prompt plus
        decoded-so-far) — what a paged pool charges, vs the reserved
        ``kv_tokens`` a contiguous allocation charges up front."""
        if not self.decoding:
            return self.prompt_total
        decoded = self.output_total - max(0.0, self.output_left)
        return self.prompt_total + int(decoded)


class TokenBucketExecutor(Executor):
    """Simulated continuous batching: exact event-driven token integration.

    Between membership changes every stream progresses linearly (prefill at
    ``prefill_tps`` unshared, decode at ``decode_tps / share`` with
    ``share = max(1, n_active / saturation)``), so it suffices to advance
    all streams to ``now`` and re-derive the next phase boundary whenever
    the batch changes — no fixed tick quantum, no drift.
    """

    def __init__(self, profile: BackendProfile,
                 page_size: Optional[int] = None,
                 kv_quant: bool = False,
                 prefix_cache: bool = False) -> None:
        self.profile = profile
        self.kv_budget = int(getattr(profile, "kv_token_budget", 0)
                             or profile.max_concurrency * KV_TOKENS_PER_STREAM)
        # page-granularity admission mode: the same KV budget expressed as a
        # pool of fixed-size pages, admitted on *prompt* pages only
        # (paged_admit_ok) — decode pages accrue as streams generate, so
        # admission matches the real paged engine's notion of "full".
        # ``kv_quant`` applies the shared quantized-pool capacity rule
        # (quantized_pages): int8 pages double the pool the same HBM holds,
        # exactly as Engine(paged=True, kv_quant) does.
        self.page_size = page_size
        self.kv_quant = bool(kv_quant)
        self.pages_total = (quantized_pages(self.kv_budget // page_size,
                                            self.kv_quant)
                            if page_size else 0)
        # cross-request prefix caching twin (DESIGN.md §6.1-prefix): the sim
        # models the *latency* effect — a request whose ``prefix_id`` is
        # resident skips ``prefix_hit_pages`` pages of prefill work — plus
        # the hit-rate EMA and resident-prefix fingerprint that routing
        # reads.  Page-pool *sharing* itself is not modeled: holdings stay
        # fully charged, so admission is conservative vs the real engine.
        # The cache is the fingerprint: an LRU of at most
        # PREFIX_FINGERPRINT_K prefix ids -> shared-prefix token length.
        self.prefix_cache = bool(prefix_cache) and page_size is not None
        self._prefix_lru: "OrderedDict[str, int]" = OrderedDict()
        self.prefix_hit_rate = 0.0
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self._streams: List[_Stream] = []
        self._last_t = 0.0
        self._pending_ev = None
        self._loop: Optional[EventLoop] = None
        self._on_complete: Optional[CompletionFn] = None

    # ------------------------------------------------------------- interface
    @property
    def n_active(self) -> int:
        return len(self._streams)

    def _pages_used(self) -> int:
        return sum(pages_for(s.tokens_held(), self.page_size)
                   for s in self._streams)

    def admit(self, item: Any) -> bool:
        qr = item
        if self.page_size:
            self._advance()          # page holdings grow with decode progress
            free = self.pages_total - self._pages_used()
            if not paged_admit_ok(free, qr.req.prompt_tokens, self.page_size,
                                  resident=bool(self._streams)):
                return False
        else:
            kv = max(1, qr.req.prompt_tokens) + max(1, qr.req.output_tokens)
            used = sum(s.kv_tokens for s in self._streams)
            # token-budget admission; an empty backend always takes one
            # request so oversized prompts cannot deadlock the queue
            if self._streams and used + kv > self.kv_budget:
                return False
        self._advance()
        cached = self._prefix_lookup(qr.req) if self.prefix_cache else 0
        self._streams.append(_Stream(qr, qr.req.prompt_tokens,
                                     qr.req.output_tokens, self._loop.now,
                                     cached_tokens=cached))
        self._reschedule()
        return True

    def _prefix_lookup(self, req: Any) -> int:
        """Sim twin of the engine's hash-chain lookup (DESIGN.md
        §6.1-prefix): cached tokens for ``req``, updating the LRU, the
        hit-rate EMA, and the cumulative hit/lookup token counters."""
        prompt = max(1, int(req.prompt_tokens))
        pid = getattr(req, "prefix_id", None)
        cached = 0
        if pid is not None:
            shared = max(0, int(getattr(req, "prefix_tokens", 0)))
            matched = min(self._prefix_lru.get(pid, 0), shared)
            cached = prefix_hit_pages(prompt, self.page_size,
                                      matched) * self.page_size
            # after this prefill the request's own shared prefix is resident
            self._prefix_lru[pid] = max(self._prefix_lru.get(pid, 0), shared)
            self._prefix_lru.move_to_end(pid)
            while len(self._prefix_lru) > PREFIX_FINGERPRINT_K:
                self._prefix_lru.popitem(last=False)
        self.prefix_lookup_tokens += prompt
        self.prefix_hit_tokens += cached
        self.prefix_hit_rate += PREFIX_HIT_EMA_BETA * (cached / prompt
                                                       - self.prefix_hit_rate)
        return cached

    def load(self) -> ExecutorLoad:
        self._advance()
        if self.page_size:
            pages_used = self._pages_used()
            kv_used = pages_used * self.page_size
            kv_budget = self.pages_total * self.page_size
        else:
            pages_used = 0
            kv_used = sum(s.kv_tokens for s in self._streams)
            kv_budget = self.kv_budget
        return ExecutorLoad(
            active_streams=len(self._streams),
            queued_streams=0,
            pending_prefill_tokens=int(sum(s.prompt_left
                                           for s in self._streams
                                           if not s.decoding)),
            pending_decode_tokens=int(sum(s.output_left
                                          for s in self._streams)),
            kv_used=kv_used,
            kv_budget=kv_budget,
            pages_used=pages_used,
            pages_total=self.pages_total,
            cache_hit_rate=self.prefix_hit_rate if self.prefix_cache else 0.0,
            resident_prefixes=tuple(
                prefix_fingerprint_id(pid)
                for pid in reversed(self._prefix_lru))
            if self.prefix_cache else ())

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        return self.profile.service_time(prompt_tokens, output_tokens,
                                         len(self._streams) + 1)

    # -------------------------------------------------------------- dynamics
    def _decode_rate(self) -> float:
        share = max(1.0, len(self._streams) / self.profile.saturation)
        return self.profile.decode_tps / share

    def _rate(self, s: _Stream, decode_rate: float) -> float:
        return decode_rate if s.decoding else self.profile.prefill_tps

    def _advance(self) -> None:
        """Integrate token progress from the last update to ``now``."""
        now = self._loop.now
        dt = now - self._last_t
        self._last_t = now
        if dt <= 0.0 or not self._streams:
            return
        dec = self._decode_rate()
        for s in self._streams:
            if s.decoding:
                s.output_left -= dec * dt
            else:
                s.prompt_left -= self.profile.prefill_tps * dt

    def _reschedule(self) -> None:
        """Re-derive the earliest phase boundary and point one event at it.

        Called after every membership change; also flips streams whose
        boundary is (numerically) now, firing completions.
        """
        done: List[_Stream] = []
        for s in self._streams:
            if not s.decoding and s.prompt_left <= _EPS:
                s.decoding = True
                s.prompt_left = 0.0
                s.first_token_at = self._loop.now
            if s.decoding and s.output_left <= _EPS:
                done.append(s)
        if done:
            for s in done:
                self._streams.remove(s)
        if self._pending_ev is not None:
            self._loop.cancel(self._pending_ev)
            self._pending_ev = None
        if self._streams:
            dec = self._decode_rate()
            dt = min((s.output_left if s.decoding else s.prompt_left)
                     / self._rate(s, dec) for s in self._streams)
            self._pending_ev = self._loop.schedule(max(0.0, dt),
                                                   self._on_boundary)
        # completions fire after the reschedule: the callback may re-enter
        # admit() (node pulls the next queued request) and reschedule again
        for s in done:
            ft = s.first_token_at if s.first_token_at is not None \
                else self._loop.now
            tr = get_tracer()
            if tr.enabled:
                rid = getattr(getattr(s.item, "req", None), "rid", "")
                tr.span("engine.prefill", rid, self.owner, s.started_at, ft,
                        prompt_tokens=s.prompt_total)
                tr.span("engine.decode", rid, self.owner, ft,
                        self._loop.now, output_tokens=s.output_total)
            self._on_complete(s.item, s.started_at, ft)

    def _on_boundary(self) -> None:
        self._pending_ev = None
        self._advance()
        self._reschedule()


class SpecTokenBucketExecutor(TokenBucketExecutor):
    """Simulated speculative-decoding backend (DESIGN.md §6.1-spec).

    Identical to ``TokenBucketExecutor`` in admission (same KV token/page
    budgets: speculation changes how fast decode *drains*, not how much KV
    a resident stream holds), but decode throughput is scaled by the
    analytic acceptance model: each target forward verifies ``spec_k``
    draft tokens and emits ``spec_expected_tokens(alpha, k)`` tokens in
    expectation, at ``1 + spec_overhead`` times the cost of a plain decode
    step (the draft forwards).  Net per-stream decode rate::

        decode_tps * spec_expected_tokens(alpha, k) / (1 + overhead) / share

    ``spec_alpha`` defaults to the same ``SPEC_ALPHA0`` constant that seeds
    the real engine's online EMA, so a freshly booted sim node and a
    freshly booted ``SpecEngineExecutor`` report the same
    ``expected_tokens_per_step`` and make identical admission decisions
    (agreement test in ``tests/test_spec.py``).
    """

    def __init__(self, profile: BackendProfile,
                 page_size: Optional[int] = None, *,
                 spec_k: int = SPEC_K, spec_alpha: float = SPEC_ALPHA0,
                 spec_overhead: float = SPEC_OVERHEAD) -> None:
        super().__init__(profile, page_size)
        self.spec_k = int(spec_k)
        self.spec_alpha = float(spec_alpha)
        self.spec_overhead = float(spec_overhead)

    def expected_tokens_per_step(self) -> float:
        return spec_expected_tokens(self.spec_alpha, self.spec_k)

    def _speedup(self) -> float:
        """Net decode-throughput multiplier (> 1 when speculation pays)."""
        return self.expected_tokens_per_step() / (1.0 + self.spec_overhead)

    def _decode_rate(self) -> float:
        return super()._decode_rate() * self._speedup()

    def load(self) -> ExecutorLoad:
        return replace(super().load(),
                       expected_tokens_per_step=self.expected_tokens_per_step())

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        return self.profile.service_time(prompt_tokens,
                                         output_tokens / self._speedup(),
                                         len(self._streams) + 1)


class DisaggTokenBucketExecutor(Executor):
    """Simulated disaggregated prefill/decode backend (DESIGN.md §6.1-disagg).

    A prefill-only and a decode-only token bucket joined by an explicit
    KV-transfer cost model.  A request moves through four stages:

    1. **prefill** — prompt tokens at ``prefill_profile.prefill_tps``
       (unshared, like the colocated backend); its prompt's KV occupies the
       *prefill* pool.  The first output token is emitted by the prefill
       side the instant prefill finishes (``first_token_at``), mirroring
       the real ``DisaggEngineExecutor``.
    2. **transfer** — the populated KV leaves the prefill pool (the copy
       frees it) and crosses the wire:
       ``transfer_s = transfer_base_s + prompt_len * kv_bytes_per_token /
       transfer_bytes_per_s``.
    3. **handoff queue** — landed transfers wait FIFO for decode-side
       admission (head-of-line blocking keeps sim and engine agreement
       deterministic).
    4. **decode** — output tokens at ``profile.decode_tps / share`` with
       the share recomputed on every decode-membership change, exactly as
       in ``TokenBucketExecutor``.

    Admission gates on **both** pools: the prompt's pages (tokens) must fit
    the free prefill pool next to the prompts currently prefilling, and its
    decode-side pages must fit the decode pool after subtracting the
    reservations of every earlier-admitted stream still staging (prefill /
    transfer / handoff) — so every accepted transfer can eventually land
    (DistServe-style decode-capacity reservation).  With ``page_size`` set
    both gates use ``paged_admit_ok``, the same rule the real engines
    apply, so sim and engine admission decisions agree on identical
    budgets.

    Like the colocated ``TokenBucketExecutor``, the sim does not model
    decode-side preemption: landing charges prompt pages only, and a
    stream's page holdings then grow with decode progress, so the decode
    pool can transiently over-occupy under pressure where the real engine
    would preempt — that shows up as zero decode headroom (clamped), not
    as an error.
    """

    def __init__(self, profile: BackendProfile,
                 prefill_profile: Optional[BackendProfile] = None, *,
                 page_size: Optional[int] = None,
                 kv_bytes_per_token: int = KV_BYTES_PER_TOKEN,
                 transfer_bytes_per_s: float = TRANSFER_BYTES_PER_S,
                 transfer_base_s: float = TRANSFER_BASE_S) -> None:
        self.profile = profile                       # decode side
        self.prefill_profile = prefill_profile or profile
        self.decode_budget = int(getattr(profile, "kv_token_budget", 0)
                                 or profile.max_concurrency
                                 * KV_TOKENS_PER_STREAM)
        self.prefill_budget = int(
            getattr(self.prefill_profile, "kv_token_budget", 0)
            or self.prefill_profile.max_concurrency * KV_TOKENS_PER_STREAM)
        self.page_size = page_size
        self.decode_pages_total = (self.decode_budget // page_size
                                   if page_size else 0)
        self.prefill_pages_total = (self.prefill_budget // page_size
                                    if page_size else 0)
        self.kv_bytes_per_token = kv_bytes_per_token
        self.transfer_bytes_per_s = transfer_bytes_per_s
        self.transfer_base_s = transfer_base_s
        self._prefill: List[_Stream] = []
        self._transfers: List[_Stream] = []    # on the wire
        self._handoffs: List[_Stream] = []     # landed, awaiting admission
        self._decode: List[_Stream] = []
        self._handoff_bytes = 0                # cumulative KV bytes on the wire
        self._last_t = 0.0
        self._pending_ev = None
        self._loop: Optional[EventLoop] = None
        self._on_complete: Optional[CompletionFn] = None

    def transfer_s(self, prompt_tokens: int) -> float:
        """Wire time for one handoff: base cost + KV bytes over the link."""
        return (self.transfer_base_s + max(1, prompt_tokens)
                * self.kv_bytes_per_token / self.transfer_bytes_per_s)

    # ------------------------------------------------------------- interface
    @property
    def n_active(self) -> int:
        return len(self._prefill) + len(self._decode)

    def _staging(self) -> List[_Stream]:
        """Streams admitted but not yet decoding — they hold decode-side
        reservations (prompt pages) so their transfer can always land."""
        return self._prefill + self._transfers + self._handoffs

    def _decode_pages_used(self) -> int:
        return sum(pages_for(s.tokens_held(), self.page_size)
                   for s in self._decode)

    def _prefill_pages_used(self) -> int:
        return sum(pages_for(s.prompt_total, self.page_size)
                   for s in self._prefill)

    def admit(self, item: Any) -> bool:
        qr = item
        self._advance()
        p, o = qr.req.prompt_tokens, qr.req.output_tokens
        staging = self._staging()
        if self.page_size:
            pre_free = self.prefill_pages_total - self._prefill_pages_used()
            if not paged_admit_ok(pre_free, p, self.page_size,
                                  resident=bool(self._prefill)):
                return False
            reserved = sum(pages_for(s.prompt_total, self.page_size)
                           for s in staging)
            free_eff = (self.decode_pages_total - self._decode_pages_used()
                        - reserved)
            if not paged_admit_ok(free_eff, p, self.page_size,
                                  resident=bool(self._decode)
                                  or bool(staging)):
                return False
        else:
            pre_used = sum(s.prompt_total for s in self._prefill)
            if self._prefill and pre_used + max(1, p) > self.prefill_budget:
                return False
            kv = max(1, p) + max(1, o)
            used = sum(s.kv_tokens for s in self._decode)
            reserved = sum(s.kv_tokens for s in staging)
            if ((self._decode or staging)
                    and used + reserved + kv > self.decode_budget):
                return False
        self._prefill.append(_Stream(qr, p, o, self._loop.now))
        self._reschedule()
        return True

    def load(self) -> ExecutorLoad:
        self._advance()
        wire = self._transfers + self._handoffs
        if self.page_size:
            pre_used = self._prefill_pages_used() * self.page_size
            pre_budget = self.prefill_pages_total * self.page_size
            pages_used = self._decode_pages_used()
            kv_used = pages_used * self.page_size
            kv_budget = self.decode_pages_total * self.page_size
        else:
            pre_used = sum(s.prompt_total for s in self._prefill)
            pre_budget = self.prefill_budget
            pages_used = 0
            kv_used = sum(s.kv_tokens for s in self._decode)
            kv_budget = self.decode_budget
        return ExecutorLoad(
            active_streams=len(self._prefill) + len(self._decode),
            queued_streams=0,
            pending_prefill_tokens=int(sum(s.prompt_left
                                           for s in self._prefill)),
            pending_decode_tokens=int(sum(s.output_left for s in self._decode)
                                      + sum(s.output_total for s in wire)),
            kv_used=kv_used,
            kv_budget=kv_budget,
            pages_used=pages_used,
            pages_total=self.decode_pages_total,
            prefill_kv_used=pre_used,
            prefill_kv_budget=pre_budget,
            transfer_inflight=len(wire),
            handoff_bytes=self._handoff_bytes)

    def estimate(self, prompt_tokens: int, output_tokens: int) -> float:
        share = max(1.0, (len(self._decode) + 1) / self.profile.saturation)
        return (prompt_tokens / self.prefill_profile.prefill_tps
                + self.transfer_s(prompt_tokens)
                + output_tokens / (self.profile.decode_tps / share))

    # -------------------------------------------------------------- dynamics
    def _decode_rate(self) -> float:
        share = max(1.0, len(self._decode) / self.profile.saturation)
        return self.profile.decode_tps / share

    def _advance(self) -> None:
        now = self._loop.now
        dt = now - self._last_t
        self._last_t = now
        if dt <= 0.0:
            return
        for s in self._prefill:
            s.prompt_left -= self.prefill_profile.prefill_tps * dt
        if self._decode:
            dec = self._decode_rate()
            for s in self._decode:
                s.output_left -= dec * dt

    def _admit_decode(self) -> None:
        """Land waiting handoffs FIFO while the decode pool takes them."""
        moved = False
        while self._handoffs:
            s = self._handoffs[0]
            if self.page_size:
                free = self.decode_pages_total - self._decode_pages_used()
                if not paged_admit_ok(free, s.prompt_total, self.page_size,
                                      resident=bool(self._decode)):
                    break
            else:
                used = sum(d.kv_tokens for d in self._decode)
                if self._decode and used + s.kv_tokens > self.decode_budget:
                    break
            self._handoffs.pop(0)
            s.decoding = True
            self._decode.append(s)
            moved = True
        if moved:
            self._reschedule()

    def _on_transfer_landed(self, s: _Stream) -> None:
        self._advance()
        self._transfers.remove(s)
        self._handoffs.append(s)
        tr = get_tracer()
        if tr.enabled:
            # the wire leg: transfer starts the instant prefill finishes
            # (first_token_at) and lands now (DESIGN.md §Observability)
            rid = getattr(getattr(s.item, "req", None), "rid", "")
            tr.span("disagg.handoff", rid, self.owner,
                    s.first_token_at if s.first_token_at is not None
                    else self._loop.now,
                    self._loop.now,
                    bytes=max(1, s.prompt_total) * self.kv_bytes_per_token)
        self._admit_decode()

    def _reschedule(self) -> None:
        """Flip phase boundaries that are (numerically) due, then point one
        event at the earliest remaining boundary.  Mirrors
        ``TokenBucketExecutor._reschedule``; the extra boundary here is
        prefill completion, which emits the first token and starts the
        KV transfer (the copy frees the prefill pool)."""
        now = self._loop.now
        handed = [s for s in self._prefill if s.prompt_left <= _EPS]
        for s in handed:
            self._prefill.remove(s)
            s.prompt_left = 0.0
            s.first_token_at = now
            self._transfers.append(s)
            self._handoff_bytes += (max(1, s.prompt_total)
                                    * self.kv_bytes_per_token)
            self._loop.schedule(self.transfer_s(s.prompt_total),
                                lambda s=s: self._on_transfer_landed(s))
        done = [s for s in self._decode if s.output_left <= _EPS]
        for s in done:
            self._decode.remove(s)
        if self._pending_ev is not None:
            self._loop.cancel(self._pending_ev)
            self._pending_ev = None
        dts = [s.prompt_left / self.prefill_profile.prefill_tps
               for s in self._prefill]
        if self._decode:
            dec = self._decode_rate()
            dts += [s.output_left / dec for s in self._decode]
        if dts:
            self._pending_ev = self._loop.schedule(max(0.0, min(dts)),
                                                   self._on_boundary)
        if done:
            # freed decode capacity lands waiting handoffs before the
            # completion callbacks re-enter admit() (node queue refill)
            self._admit_decode()
            for s in done:
                ft = s.first_token_at if s.first_token_at is not None \
                    else now
                tr = get_tracer()
                if tr.enabled:
                    rid = getattr(getattr(s.item, "req", None), "rid", "")
                    tr.span("engine.prefill", rid, self.owner,
                            s.started_at, ft, prompt_tokens=s.prompt_total)
                    # covers wire + handoff queue + decode (the nested
                    # disagg.handoff span shows the wire leg)
                    tr.span("engine.decode", rid, self.owner, ft, now,
                            output_tokens=s.output_total, stage="disagg")
                self._on_complete(s.item, s.started_at, ft)

    def _on_boundary(self) -> None:
        self._pending_ev = None
        self._advance()
        self._reschedule()
