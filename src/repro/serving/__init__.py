from repro.serving.engine import Engine, EngineStats, GenRequest, KVHandoff
from repro.serving.executor import (DisaggEngineExecutor, EngineExecutor,
                                    SpecEngineExecutor)
from repro.serving.sampling import sample
