"""The credit market at work (paper Fig 6): better service -> more credit.

Three classes of providers serve the same traffic; the duel-and-judge
mechanism plus PoS routing moves credit toward the higher-quality/faster
ones, with no coordinator deciding anything.

    PYTHONPATH=src python examples/decentralized_market.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks/

from benchmarks.quality import run_experiment_avg as run_experiment


def main() -> None:
    for name in ("model_capacity", "hardware"):
        r = run_experiment(name)
        print(f"\n=== {name} ===")
        print(f"{'class':14s} {'credit growth':>14s} {'served':>8s} "
              f"{'duel win rate':>14s}")
        for cname, v in r["classes"].items():
            print(f"{cname:14s} {v['credit']:14.1f} {v['served']:8d} "
                  f"{v['win_rate']:14.2f}")
        credits = [v["credit"] for v in r["classes"].values()]
        assert credits == sorted(credits, reverse=True), \
            "credit should decrease with class quality"
    print("\ncredit ordered by service quality in both experiments — "
          "the market rewards better providers (Theorem 5.8 in action).")


if __name__ == "__main__":
    main()
