"""Gossip convergence, PoS sampling statistics, duel-and-judge behaviour."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.duel import DuelParams, expected_extra_requests, run_duel
from repro.core.gossip import (PeerRecord, PeerView, gossip_round,
                               rounds_to_convergence)
from repro.core.pos import pos_sample, pos_sample_one, selection_probs
from repro.sim.executor import (ExecutorLoad, digest_staleness_weight,
                                make_load_digest)
from repro.sim.servicemodel import DIGEST_STALENESS_TAU_S


def _digest(now, kv_used=0, kv_budget=100, handoff_bytes=0):
    """A test digest, built through the sanctioned executor-layer
    projection (layering/digest-construction)."""
    return make_load_digest(ExecutorLoad(
        active_streams=0, queued_streams=0, pending_prefill_tokens=0,
        pending_decode_tokens=0, kv_used=kv_used, kv_budget=kv_budget,
        handoff_bytes=handoff_bytes), now)


class TestGossip:
    def test_pairwise_merge_reconciles(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        a.heartbeat(1.0)
        b.set_addr("tcp://b2", 1.0)
        gossip_round(a, b)
        assert a.records["b"].addr == "tcp://b2"
        assert b.records["a"].version == a.records["a"].version

    def test_offline_then_revive_wins_by_version(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        gossip_round(a, b)
        a.set_offline(2.0)
        gossip_round(a, b)
        assert not b.records["a"].online
        a.go = None
        a.heartbeat(3.0)       # revive bumps version again
        gossip_round(a, b)
        assert b.records["a"].online

    @staticmethod
    def _triangle():
        a, b, c = (PeerView(x, f"tcp://{x}") for x in "abc")
        for v in (a, b, c):
            for w in (a, b, c):
                if v is not w:
                    gossip_round(v, w)
        return a, b, c

    def test_dead_report_spreads_to_consensus(self):
        """Dead reports are epidemic (DESIGN.md §6.2-gossip): an offline
        mark at the suspected version beats the live record on merge, so
        peers that never timed the origin out themselves still learn the
        suspicion."""
        a, b, c = self._triangle()
        # b stops heartbeating; only a suspects after timeout
        a.suspect_failures(100.0, suspect_after=5.0)
        assert not a.records["b"].online
        gossip_round(a, c)                 # c never suspected b itself
        assert not c.records["b"].online   # ... but takes the dead report

    def test_revived_origin_heartbeat_beats_dead_report(self):
        a, b, c = self._triangle()
        a.suspect_failures(100.0, suspect_after=5.0)
        gossip_round(a, c)                 # rumor has spread to c
        assert not c.records["b"].online
        b.heartbeat(101.0)                 # a live b bumps its own version
        gossip_round(b, c)
        assert c.records["b"].online       # strictly-higher version wins
        gossip_round(c, a)
        assert a.records["b"].online       # ... and overrides the reporter

    def test_self_refutation_jumps_past_report_version(self):
        a, b, _ = self._triangle()
        a.suspect_failures(100.0, suspect_after=5.0)
        v_report = a.records["b"].version
        gossip_round(a, b)                 # b hears the rumor about itself
        assert b.records["b"].online
        assert b.records["b"].version > v_report
        gossip_round(a, b)                 # the refutation wins the merge
        assert a.records["b"].online

    @given(st.integers(3, 12), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_convergence_within_log_rounds(self, n, seed):
        rng = np.random.default_rng(seed)
        views = [PeerView(f"n{i}", f"tcp://n{i}") for i in range(n)]
        # bootstrap: ring introduction
        for i in range(n):
            gossip_round(views[i], views[(i + 1) % n])
        for v in views:
            v.heartbeat(1.0)
        rounds = rounds_to_convergence(views, rng, fanout=2)
        assert rounds <= 2 * int(np.ceil(np.log2(n))) + 3


class TestLoadDigests:
    """The load-dissemination plane (DESIGN.md §6.2-gossip): digests ride
    the per-origin versioned heartbeat records, so anti-entropy merging
    propagates the freshest load picture for free."""

    def test_digest_rides_heartbeat_and_gossip(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        d = _digest(1.0, kv_used=50)
        a.heartbeat(1.0, digest=d)
        gossip_round(a, b)
        assert b.digest_of("a") == d
        assert b.digest_of("nobody") is None

    def test_heartbeat_without_digest_keeps_last_published(self):
        a = PeerView("a", "tcp://a")
        d = _digest(1.0, kv_used=50)
        a.heartbeat(1.0, digest=d)
        a.heartbeat(2.0)                  # membership-only heartbeat
        assert a.digest_of("a") == d

    def test_newer_digest_wins_merge(self):
        a = PeerView("a", "tcp://a")
        b = PeerView("b", "tcp://b")
        a.heartbeat(1.0, digest=_digest(1.0, kv_used=10))
        gossip_round(a, b)
        d2 = _digest(2.0, kv_used=90)
        a.heartbeat(2.0, digest=d2)
        gossip_round(a, b)
        assert b.digest_of("a") == d2

    @given(st.integers(3, 10), st.integers(0, 500))
    @settings(max_examples=15, deadline=None)
    def test_digest_convergence_within_log_rounds(self, n, seed):
        """``rounds_to_convergence`` compares the digest payloads, not just
        membership: after it returns, every node holds every other node's
        published digest."""
        rng = np.random.default_rng(seed)
        views = [PeerView(f"n{i}", f"tcp://n{i}") for i in range(n)]
        for i in range(n):
            gossip_round(views[i], views[(i + 1) % n])
        for i, v in enumerate(views):
            v.heartbeat(1.0, digest=_digest(1.0, kv_used=i))
        rounds = rounds_to_convergence(views, rng, fanout=2)
        assert rounds <= 2 * int(np.ceil(np.log2(n))) + 3
        for v in views:
            for i in range(n):
                d = v.digest_of(f"n{i}")
                assert d is not None and d.prefill_headroom == \
                    pytest.approx(1.0 - i / 100)

    def test_staleness_weight_decays_toward_prior(self):
        assert digest_staleness_weight(0.0) == pytest.approx(1.0)
        assert digest_staleness_weight(DIGEST_STALENESS_TAU_S) == \
            pytest.approx(float(np.exp(-1)))
        ws = [digest_staleness_weight(t) for t in (0.0, 1.0, 5.0, 20.0, 100.0)]
        assert all(x > y for x, y in zip(ws, ws[1:]))
        # clock skew between origin timestamps and the local clock clamps
        # to full trust rather than extrapolating weights above 1
        assert digest_staleness_weight(-3.0) == pytest.approx(1.0)

    def test_view_cap_evicts_stalest_heartbeats(self):
        v = PeerView("a", "tcp://a", view_cap=2)
        v.merge([PeerRecord("b", 1, True, "tcp://b", 1.0),
                 PeerRecord("c", 1, True, "tcp://c", 2.0),
                 PeerRecord("d", 1, True, "tcp://d", 3.0)])
        # the cap bounds *remote* records; self is never evicted
        assert set(v.records) == {"a", "c", "d"}


class TestPoS:
    def test_probs_proportional_to_stake(self):
        stakes = {"a": 1.0, "b": 3.0, "c": 6.0}
        p = selection_probs(stakes, ["a", "b", "c"])
        assert p["c"] == pytest.approx(0.6)
        assert p["b"] == pytest.approx(0.3)

    def test_zero_stake_uniform_fallback(self):
        p = selection_probs({}, ["a", "b"])
        assert p["a"] == pytest.approx(0.5)

    def test_empirical_selection_frequency(self):
        rng = np.random.default_rng(0)
        stakes = {"a": 1.0, "b": 2.0, "c": 4.0}
        counts = {"a": 0, "b": 0, "c": 0}
        for _ in range(4000):
            counts[pos_sample_one(stakes, list(stakes), rng)] += 1
        assert counts["c"] / 4000 == pytest.approx(4 / 7, abs=0.03)
        assert counts["b"] / 4000 == pytest.approx(2 / 7, abs=0.03)

    @given(st.integers(1, 5), st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_sample_without_replacement(self, k, seed):
        rng = np.random.default_rng(seed)
        stakes = {f"n{i}": float(i + 1) for i in range(6)}
        got = pos_sample(stakes, list(stakes), k, rng, exclude=["n0"])
        assert len(got) == k
        assert len(set(got)) == k
        assert "n0" not in got


class TestDuel:
    def test_outcome_credit_flow(self):
        rng = np.random.default_rng(0)
        params = DuelParams(r_add=2.0, penalty=1.5, judge_fee=0.25)
        out = run_duel("d0", "hi", "lo", ["j1", "j2"],
                       {"hi": 0.95, "lo": 0.05}, params, rng)
        kinds = [op.kind for op in out.ops]
        assert kinds.count("transfer") == 3       # winner + 2 judges
        assert kinds.count("slash") == 1
        total_minted = sum(op.amount for op in out.ops
                           if op.kind == "transfer")
        assert total_minted == pytest.approx(2.0 + 2 * 0.25)

    def test_quality_wins_statistically(self):
        rng = np.random.default_rng(1)
        params = DuelParams(judge_accuracy=0.9)
        wins = sum(run_duel(f"d{i}", "hi", "lo", ["j1", "j2", "j3"],
                            {"hi": 0.8, "lo": 0.3}, params, rng).winner == "hi"
                   for i in range(500))
        # P(hi true-wins) = 0.75; judges 90% accurate majority-of-3
        assert 0.6 < wins / 500 < 0.9

    def test_overhead_formula(self):
        assert expected_extra_requests(1000, 0.5, 0.1, 2) == pytest.approx(150)
