"""A WWW.Serve node (paper Figure 2).

Each node bundles the five managers:

* **Request Manager** — local + delegated queues, admission timestamps.
* **Policy Manager**  — ``NodePolicy`` decisions (offload / accept / priority).
* **Ledger Manager**  — either a shared ledger handle or a local CreditChain.
* **Model Manager**   — a pluggable ``Executor`` backend (DESIGN.md §6.1).
  Inside the event-loop simulation this is the continuous-batching
  ``TokenBucketExecutor`` (default).  The real JAX ``EngineExecutor``
  implements the same contract but runs in wall-clock time on
  ``GenRequest`` payloads, so it is pumped by the serving driver
  (``repro.launch.serve``) rather than scheduled by the sim loop.
* **Communication Manager** — message send via the network bus (latency
  injected by the event loop; ZeroMQ ROUTER in the paper).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, TYPE_CHECKING

import numpy as np

from repro.core.gossip import PeerView
from repro.core.policy import NodePolicy
from repro.obs import get_tracer
from repro.sim.executor import Executor, TokenBucketExecutor
from repro.sim.servicemodel import BackendProfile
from repro.sim.workload import Request

if TYPE_CHECKING:
    from repro.core.network import Network


@dataclass
class QueuedRequest:
    req: Request
    enqueue_time: float
    delegated: bool
    origin_node: str              # who the response must be returned to
    duel_id: Optional[str] = None # set if this execution is part of a duel
    started_at: Optional[float] = None      # executor admission time
    first_token_at: Optional[float] = None  # prefill done, first decode token
    queued_at: Optional[float] = None       # arrival at the LAST hop's queue
                                            # (enqueue_time is preserved
                                            # across delegation/bounces, so
                                            # the trace plane needs its own
                                            # last-hop stamp)


class Node:
    def __init__(self, node_id: str, profile: BackendProfile,
                 policy: Optional[NodePolicy] = None,
                 quality: Optional[float] = None,
                 executor_factory: Optional[Callable[["Node"], Executor]] = None,
                 view_cap: Optional[int] = None,
                 ) -> None:
        self.id = node_id
        self.profile = profile
        self.policy = policy or NodePolicy()
        self.quality = profile.quality if quality is None else quality
        self.secret = node_id.encode() + b"-secret"
        self.view = PeerView(node_id, addr=f"tcp://{node_id}:5555",
                             view_cap=view_cap)
        self.online = True

        # Request Manager state
        self.local_queue: List[QueuedRequest] = []
        self.delegated_queue: List[QueuedRequest] = []

        # Model Manager: the executor is bound when the node joins a network
        # (it needs the network's clock)
        self._executor_factory = executor_factory or (
            lambda node: TokenBucketExecutor(node.profile))
        self.executor: Optional[Executor] = None

        # stats
        self.served_total = 0
        self.served_delegated = 0
        self.duel_wins = 0
        self.duel_losses = 0

        self.network: Optional["Network"] = None  # set on Network.add_node

    def bind_executor(self, loop) -> None:
        self.executor = self._executor_factory(self)
        self.executor.owner = self.id       # trace span identity
        self.executor.bind(loop, self._on_exec_complete)

    def publish_digest(self, now: float) -> None:
        """Heartbeat with a fresh load digest piggybacked on the membership
        record (DESIGN.md §6.2-gossip)."""
        digest = self.executor.digest(now) if self.executor is not None else None
        self.view.heartbeat(now, digest=digest)

    # ------------------------------------------------------------------ utils
    @property
    def n_active(self) -> int:
        return self.executor.n_active if self.executor is not None else 0

    @property
    def queue_len(self) -> int:
        return len(self.local_queue) + len(self.delegated_queue)

    def utilization(self) -> float:
        return self.executor.load().active_streams / max(
            1, self.profile.saturation)

    def balance(self) -> float:
        return self.network.ledger_balance(self.id)

    # --------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        """User submits a request to this node (paper Fig 9, Step 1)."""
        assert self.network is not None
        if not self.online:
            # user traffic to an offline node is re-targeted by the network
            self.network.resubmit_elsewhere(req)
            return
        net, rng = self.network, self.network.rng
        # Step 2: local vs offload decision (Policy Manager)
        if (net.mode == "decentralized"
                and self.policy.wants_offload(self.queue_len, self.n_active,
                                              self.profile.saturation,
                                              self.balance(), rng)):
            if net.try_offload(self, req):
                return
        tr = get_tracer()
        if tr.enabled:
            tr.span("route.decide", req.rid, self.id, req.arrival,
                    net.loop.now, mode=net.mode, outcome="local")
        self.enqueue(QueuedRequest(req, net.loop.now, delegated=False,
                                   origin_node=self.id))

    def enqueue(self, qr: QueuedRequest) -> None:
        if not self.online:
            # delegation/duel deliveries race with churn: the message was in
            # flight when this node went offline, so bounce it back to the
            # network instead of re-stranding it in a drained queue
            self.network.on_queued_dropped(self, qr)
            return
        qr.queued_at = self.network.loop.now
        (self.delegated_queue if qr.delegated else self.local_queue).append(qr)
        self._maybe_start()

    def _pop_next(self) -> Optional[QueuedRequest]:
        if self.policy.prioritize_local:
            for q in (self.local_queue, self.delegated_queue):
                if q:
                    return q.pop(0)
            return None
        both = self.local_queue + self.delegated_queue
        if not both:
            return None
        qr = min(both, key=lambda x: x.enqueue_time)
        (self.local_queue if not qr.delegated else self.delegated_queue).remove(qr)
        return qr

    def _maybe_start(self) -> None:
        while self.online and self.queue_len > 0:
            qr = self._pop_next()
            if qr is None:
                break
            if not self.executor.admit(qr):
                # KV headroom exhausted: put it back at the head of its queue
                # and retry when a completion frees budget
                q = self.delegated_queue if qr.delegated else self.local_queue
                q.insert(0, qr)
                break
            tr = get_tracer()
            if tr.enabled:
                now = self.network.loop.now
                t0 = qr.queued_at if qr.queued_at is not None \
                    else qr.enqueue_time
                tr.span("executor.queue", qr.req.rid, self.id, t0, now,
                        delegated=qr.delegated)
                tr.event("executor.admit", qr.req.rid, self.id, now,
                         active=self.executor.n_active)

    def _on_exec_complete(self, qr: QueuedRequest, started_at: float,
                          first_token_at: float) -> None:
        qr.started_at = started_at
        qr.first_token_at = first_token_at
        self.served_total += 1
        if qr.delegated:
            self.served_delegated += 1
        self.network.on_request_finished(self, qr)
        self._maybe_start()

    # ------------------------------------------------------------------ churn
    def go_offline(self) -> None:
        self.online = False
        self.view.set_offline(self.network.loop.now)
        # in-flight streams drain to completion, but queued (not yet started)
        # requests would otherwise be stranded until this node happens to
        # rejoin — hand them back to the network (paper Fig 5 churn)
        stranded = self.local_queue + self.delegated_queue
        self.local_queue, self.delegated_queue = [], []
        for qr in stranded:
            self.network.on_queued_dropped(self, qr)

    def go_online(self) -> None:
        self.online = True
        self.view.heartbeat(self.network.loop.now)
        self.network.resync_chain(self.id)   # catch up on missed blocks
        self._maybe_start()
