"""compat-boundary: version-gated JAX symbols live only in repro.compat.

The supported JAX range (ROADMAP, "Supported environment") spans 0.4.37
through the modern >=0.5 mesh-context API, and the symbols whose presence
or signature varies across that range may only be touched from
``src/repro/compat/`` (``meshenv``, ``pallascompat``).  The original
guard was a token grep; this checker is import/attribute-aware, so it

* catches ``from jax.sharding import use_mesh``, ``jax.sharding.set_mesh``,
  aliased module imports, bare uses of a gated name, and the
  ``axis_types=`` keyword — wherever they appear in real code;
* does NOT fire on docstrings or comments that merely *mention* a gated
  symbol (the grep's false-positive class, which forced whole-file
  allowlists).
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.framework import Checker, Finding, RepoIndex, register

# symbols whose presence/signature varies across the supported JAX range
GATED_SYMBOLS = frozenset({
    "get_abstract_mesh", "AxisType", "thread_resources",
    "use_mesh", "set_mesh", "CompilerParams", "TPUCompilerParams",
})
# call keywords with the same version-gating problem
GATED_KWARGS = frozenset({"axis_types"})

# the compat package IS the sanctioned home; its tests exercise both API
# generations by construction
ALLOWED_PREFIXES = ("src/repro/compat/",)
ALLOWED_FILES = ("tests/test_compat.py",)

_HINT = "route through repro.compat (meshenv / pallascompat) instead"


def _allowed(rel: str) -> bool:
    return rel in ALLOWED_FILES or any(rel.startswith(p)
                                       for p in ALLOWED_PREFIXES)


@register
class CompatBoundaryChecker(Checker):
    rule_id = "compat-boundary"
    description = ("version-gated jax.sharding/Pallas symbols are "
                   "resolvable only from repro.compat")

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        for rel in repo.py_files():
            if _allowed(rel):
                continue
            tree = repo.tree(rel)
            if tree is None:
                continue
            yield from self._check_module(rel, tree)

    def _check_module(self, rel: str, tree: ast.Module) -> List[Finding]:
        out: List[Finding] = []

        def hit(node: ast.AST, name: str, how: str) -> None:
            out.append(Finding(
                self.rule_id, rel, node.lineno,
                f"version-gated symbol '{name}' {how}; {_HINT}"))

        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and node.module.split(".")[0] == "jax":
                for alias in node.names:
                    if alias.name in GATED_SYMBOLS:
                        hit(node, alias.name,
                            f"imported from {node.module}")
                    elif alias.name == "*":
                        hit(node, "*",
                            f"star-imported from {node.module} "
                            f"(unanalyzable; gated symbols may leak)")
            elif isinstance(node, ast.Attribute) \
                    and node.attr in GATED_SYMBOLS:
                hit(node, node.attr, "accessed as an attribute")
            elif isinstance(node, ast.Name) and node.id in GATED_SYMBOLS \
                    and isinstance(node.ctx, ast.Load):
                hit(node, node.id, "referenced by name")
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in GATED_KWARGS:
                        hit(node, f"{kw.arg}=", "passed as a call keyword")
        return out
