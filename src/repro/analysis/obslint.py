"""obs-lint: tracing and wall-clock accounting go through ``repro.obs``.

The observability plane (DESIGN.md §Observability) only reconstructs
request latency if every instrumented layer emits spans through the one
``Tracer`` API and stamps wall time through the one sanctioned clock.
Three sub-rules, same shape as ``layering/digest-construction``:

* ``obs-lint/span-construction`` — ``Span(...)`` is constructed only
  inside ``src/repro/obs/``; everyone else records via ``Tracer.span`` /
  ``Tracer.event`` / ``Tracer.wall``, so a disabled tracer stays a cheap
  no-op and span streams stay well-formed.
* ``obs-lint/wall-clock`` — the instrumented modules (network, node, the
  sim and engine executors, the engine) never call ``time.perf_counter``
  / ``time.time`` / ``time.monotonic`` directly: wall timestamps come
  from ``repro.obs.wall_now()`` and measured blocks from
  ``Tracer.wall(...)``, keeping one auditable time base per clock
  domain.
* ``obs-lint/emission`` — each instrumented module actually resolves the
  process tracer (``get_tracer``): deleting the lifecycle spans from a
  governed file is a contract break, not a cleanup.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.framework import Checker, Finding, RepoIndex, register

# the one sanctioned home of Span construction and raw clock reads
OBS_HOME_PREFIX = "src/repro/obs/"
SPAN_CTOR = "Span"

# modules that carry the per-request lifecycle spans (DESIGN.md
# §Observability) and therefore both (a) must keep emitting them and
# (b) must stamp wall time only through repro.obs
GOVERNED_FILES = (
    "src/repro/core/network.py",
    "src/repro/core/node.py",
    "src/repro/sim/executor.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/executor.py",
)

# raw clock reads banned in governed files (wall_now() / Tracer.wall
# wrap perf_counter; the sim layers read EventLoop.now)
_CLOCK_ATTRS = frozenset({"perf_counter", "monotonic"})


def _is_span_ctor(node: ast.Call) -> bool:
    f = node.func
    return ((isinstance(f, ast.Name) and f.id == SPAN_CTOR)
            or (isinstance(f, ast.Attribute) and f.attr == SPAN_CTOR))


def _raw_clock_name(node: ast.Call):
    """The offending clock's name, or None.  Named per call form so
    distinct reads in one module stay distinct findings (the framework
    dedupes on (rule, path, msg))."""
    f = node.func
    if isinstance(f, ast.Name) and f.id in _CLOCK_ATTRS:
        return f.id                      # from time import perf_counter
    if isinstance(f, ast.Attribute):
        if f.attr in _CLOCK_ATTRS:
            return f"time.{f.attr}"      # time.perf_counter()
        # time.time() — attr "time" alone is too generic, so require the
        # receiver to be the time module by name
        if (f.attr == "time" and isinstance(f.value, ast.Name)
                and f.value.id == "time"):
            return "time.time"
    return None


@register
class ObsLintChecker(Checker):
    rule_id = "obs-lint"
    description = ("Span construction confined to repro.obs; governed "
                   "network/executor/engine modules emit spans and stamp "
                   "wall time through the repro.obs API")

    def run(self, repo: RepoIndex) -> Iterable[Finding]:
        for rel in repo.py_files():
            tree = repo.tree(rel)
            if tree is None:
                continue
            in_obs = rel.startswith(OBS_HOME_PREFIX)
            governed = rel in GOVERNED_FILES
            if not in_obs:
                for node in ast.walk(tree):
                    if isinstance(node, ast.Call) and _is_span_ctor(node):
                        yield Finding(
                            "obs-lint/span-construction", rel, node.lineno,
                            "Span constructed outside repro.obs (record "
                            "via Tracer.span/event/wall so disabled "
                            "tracing stays a no-op; DESIGN.md "
                            "§Observability)")
            if not governed:
                continue
            saw_tracer = False
            for node in ast.walk(tree):
                clock = (_raw_clock_name(node)
                         if isinstance(node, ast.Call) else None)
                if clock is not None:
                    yield Finding(
                        "obs-lint/wall-clock", rel, node.lineno,
                        f"raw clock read ({clock}) in an instrumented "
                        f"module (stamp through repro.obs.wall_now() or a "
                        f"Tracer.wall block; DESIGN.md §Observability)")
                elif (isinstance(node, ast.Call)
                      and isinstance(node.func, ast.Name)
                      and node.func.id == "get_tracer"):
                    saw_tracer = True
            if not saw_tracer:
                yield Finding(
                    "obs-lint/emission", rel, 1,
                    "instrumented module no longer resolves the tracer "
                    "(get_tracer): the lifecycle spans of DESIGN.md "
                    "§Observability must keep being emitted here")
